//! Model serving: a request router, dynamic batcher, and a sharded scorer
//! worker pool over a compiled [`crate::infer::ScoringPlan`], with the
//! batched compute running through the PJRT artifacts (L1 Pallas kernels)
//! when available and the rust-native plan otherwise.
//!
//! Architecture (vLLM-router-shaped, scaled to a classifier):
//!
//! ```text
//!  clients ──▶ ServerHandle::submit ──▶ bounded queue ──▶ batcher thread
//!                                                          │ (collect up to
//!                                                          │  max_batch or
//!                                                          │  max_wait)
//!                                                          ▼
//!                                         one ShardJob per SV shard
//!                                          │          │          │
//!                                          ▼          ▼          ▼
//!                                      scorer-0   scorer-1 …  scorer-N
//!                                      (shard 0)  (shard 1)   (shard s%N)
//!                                          │          │          │
//!                                          └───── shard-reduce ──┘
//!                                         (partial kernel sums; the last
//!                                          worker to finish finalizes)
//!                                                          │
//!  client ◀─── oneshot reply channel ◀─────────────────────┘
//! ```
//!
//! The batcher amortizes dispatch overhead; the scorer workers split each
//! batch across the support-vector shards of a [`ShardedPlan`] and reduce
//! the partial kernel sums before replying. With `shards == 1` the workers
//! instead pipeline *whole* batches (replication): the batcher assembles
//! batch k+1 while a worker scores batch k. Sharding wins when a single
//! batch against a large expansion dominates latency; replication wins for
//! small models under high request concurrency.
//!
//! Multiclass serving ([`serve_multiclass`]) runs the same runtime over one
//! sharded plan per one-vs-rest class: each batch fans out as one shard job
//! per `(class, shard)` pair, partial sums land in a class-major
//! accumulator, and the last worker reduces it to argmax + per-class
//! margins ([`MultiScore`]) via the shared [`crate::infer::argmax_class`]
//! rule — so serving agrees with offline
//! [`crate::infer::MulticlassPlan`] predictions by construction.
//!
//! Shutdown is sender-driven: [`ServerHandle::stop`] drops the request
//! sender, the batcher drains the queue and exits on `Disconnected` (no
//! poll timeout), closes the scorer job queue, joins its workers, and
//! `stop()` joins the batcher.
//!
//! **Hardening contract** (what the network frontend in [`crate::net`]
//! leans on):
//!
//! * Requests are validated before queueing — dimensions, the CSR
//!   contract, *and finiteness*: one NaN/±inf feature would silently
//!   poison the shared accumulator (and every argmax sharing its batch),
//!   so non-finite values are rejected typed ([`SubmitError::Invalid`]),
//!   matching the libsvm parser's non-finite-label contract.
//! * A panicking scorer cannot hang clients or shrink the pool: every
//!   shard job holds an RAII guard that decrements the batch's `pending`
//!   count even during unwind (the last guard always finalizes), the
//!   batch is marked failed so affected clients get
//!   [`SubmitError::Failed`] instead of a hang, and `catch_unwind` keeps
//!   the worker thread alive (panics are counted in
//!   [`ServeMetrics::scorer_panics`], injectable via
//!   [`ServerHandle::inject_scorer_panics`]).
//! * Backpressure is bounded end to end: the request queue is
//!   `queue_depth`-bounded, the shard-job queue is a bounded
//!   [`WorkQueue`] (the batcher blocks instead of piling jobs ahead of
//!   slow scorers), and [`ServerHandle::try_score`]-family submissions
//!   shed with [`SubmitError::Overloaded`] when the request queue is full
//!   instead of blocking — the admission-control path the TCP frontend
//!   answers with a typed `Overloaded` wire reply.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::data::RowRef;
use crate::infer::ShardedPlan;
use crate::kernel::KernelKind;
use crate::multiclass::MulticlassModel;
use crate::odm::OdmModel;
use crate::runtime::XlaEngine;
use crate::util::pool::WorkQueue;
use crate::Result;

/// Scoring backend. Servers usually start from a typed artifact
/// ([`crate::api::Artifact::serve`] routes binary models through [`serve`]
/// and multiclass models through [`serve_multiclass`]).
#[derive(Default)]
pub enum Backend {
    /// rust-native compiled scoring plan.
    #[default]
    Native,
    /// PJRT artifacts (Pallas kernels); models without a PJRT tile layout
    /// fall back to the native plan per batch.
    Xla(XlaEngine),
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max requests per batch (defaults to the artifact decision tile).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch. `Duration::ZERO` is
    /// valid: each batch is whatever the queue already holds.
    pub max_wait: Duration,
    /// Bounded queue depth (backpressure: submit blocks when full).
    pub queue_depth: usize,
    /// Scorer worker threads draining the shard-job queue.
    pub workers: usize,
    /// Support-vector shards the plan is split into (clamped to the
    /// expansion size; linear models always compile to one shard).
    pub shards: usize,
    /// Coefficient storage precision for the compiled plan. `None` (the
    /// default) inherits the artifact's recorded knob when serving through
    /// [`crate::api::Artifact`], else f64; `Some` forces it.
    pub precision: Option<crate::infer::PlanPrecision>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let w = crate::util::pool::num_cpus().clamp(1, 8);
        Self {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_depth: 4096,
            workers: w,
            shards: w,
            precision: None,
        }
    }
}

/// A structurally invalid [`ServeConfig`] — returned by
/// [`ServeConfig::validate`] at [`serve`] time instead of letting the bad
/// value panic or hang the batcher downstream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// `max_batch == 0`: the batcher could never dispatch anything.
    ZeroMaxBatch,
    /// `queue_depth == 0`: rendezvous channels would deadlock submit.
    ZeroQueueDepth,
    /// `workers == 0`: no scorer thread would ever drain the job queue.
    ZeroWorkers,
    /// `shards == 0`: every batch would dispatch zero shard jobs and hang.
    ZeroShards,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroMaxBatch => write!(f, "serve config: max_batch must be >= 1"),
            ConfigError::ZeroQueueDepth => write!(f, "serve config: queue_depth must be >= 1"),
            ConfigError::ZeroWorkers => write!(f, "serve config: workers must be >= 1"),
            ConfigError::ZeroShards => write!(f, "serve config: shards must be >= 1"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl ServeConfig {
    /// Check the structural invariants ([`serve`] calls this before
    /// spawning anything).
    pub fn validate(&self) -> std::result::Result<(), ConfigError> {
        if self.max_batch == 0 {
            return Err(ConfigError::ZeroMaxBatch);
        }
        if self.queue_depth == 0 {
            return Err(ConfigError::ZeroQueueDepth);
        }
        if self.workers == 0 {
            return Err(ConfigError::ZeroWorkers);
        }
        if self.shards == 0 {
            return Err(ConfigError::ZeroShards);
        }
        Ok(())
    }
}

/// Typed outcome of a request submission. The blocking `score*` methods
/// convert these into crate errors; the admission-controlled `try_score*`
/// methods (and the [`crate::net`] frontend, which maps them onto wire
/// error codes) return them directly.
#[derive(Debug)]
pub enum SubmitError {
    /// The bounded request queue was full at submit time — the request was
    /// shed without blocking (admission control under overload).
    Overloaded,
    /// The server is stopped or stopping: the request was not queued, or
    /// was dropped during shutdown before a reply was produced.
    Stopped,
    /// The request is invalid: dimension mismatch, CSR contract violation,
    /// non-finite feature values, or the wrong request shape for the model
    /// (binary vs multiclass).
    Invalid(String),
    /// The batch this request joined failed server-side (a scorer worker
    /// panicked mid-batch) — the request was not scored.
    Failed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "server overloaded: request shed"),
            SubmitError::Stopped => write!(f, "server stopped"),
            SubmitError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            SubmitError::Failed => write!(f, "batch failed: scorer worker panicked"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One multiclass decision: the winning class index plus every class's
/// one-vs-rest margin. Ties take the lowest class index, matching
/// [`crate::infer::argmax_class`].
#[derive(Clone, Debug)]
pub struct MultiScore {
    /// Predicted class index (into the model's `class_labels`).
    pub argmax: usize,
    /// Per-class one-vs-rest decision values, length `n_classes`.
    pub scores: Vec<f64>,
}

/// What a server sends back: a binary decision value, a multiclass
/// argmax + margins, or a typed batch failure (scorer panic — the client
/// gets an error instead of a hang).
enum Reply {
    Score(f64),
    Multi(MultiScore),
    Failed,
}

/// One scoring request: feature row in, reply out.
struct Request {
    x: RowOwned,
    reply: SyncSender<Reply>,
    enqueued: Instant,
}

/// An owned request row — dense copy or CSR pair. Sparse requests carry
/// O(nnz) bytes through the queue and score in O(nnz) on linear models.
enum RowOwned {
    Dense(Vec<f32>),
    Sparse { indices: Vec<u32>, values: Vec<f32>, cols: usize },
}

impl RowOwned {
    fn as_row_ref(&self) -> RowRef<'_> {
        match self {
            RowOwned::Dense(x) => RowRef::Dense(x),
            RowOwned::Sparse { indices, values, cols } => {
                RowRef::Sparse { indices, values, cols: *cols }
            }
        }
    }
}

/// Number of log₂ latency buckets: bucket b counts requests whose
/// end-to-end latency landed in `[2^b, 2^(b+1))` microseconds, so the top
/// bucket covers everything ≥ ~9 minutes.
const LAT_BUCKETS: usize = 30;

/// Lock-free log₂-bucketed latency histogram (2× worst-case resolution —
/// percentiles report the closing bucket's upper bound).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
}

impl LatencyHistogram {
    /// Fresh, empty histogram (metrics embed one; tests and benches build
    /// their own).
    pub fn new() -> Self {
        LatencyHistogram { buckets: (0..LAT_BUCKETS).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Record one latency sample, in microseconds.
    pub fn record_us(&self, us: u64) {
        let b = (63 - us.max(1).leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// The `p`-th percentile (`0 < p <= 100`) in milliseconds, or `None`
    /// when no samples have been recorded — an idle histogram has no
    /// latency to report, and the old 0-sample path fabricated a ~1 µs
    /// "percentile" out of the first bucket's upper bound. Reported values
    /// are the closing bucket's upper bound: always >= the exact sample
    /// percentile and <= 2x it (log₂ buckets).
    pub fn percentile(&self, p: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, c) in self.buckets.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return Some((1u64 << (b + 1)) as f64 / 1e3);
            }
        }
        Some((1u64 << LAT_BUCKETS) as f64 / 1e3)
    }

    /// [`LatencyHistogram::percentile`] flattened for report strings:
    /// empty histograms read 0 (explicitly *not* a measured latency —
    /// JSON surfaces use the `Option` form and emit `null` instead).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile(p).unwrap_or(0.0)
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregate serving metrics.
#[derive(Debug)]
pub struct ServeMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Total queue wait across requests, microseconds.
    pub queue_wait_us: AtomicU64,
    /// Total scoring time across batches (dispatch → last shard reduced),
    /// microseconds.
    pub score_us: AtomicU64,
    /// Rows of padding wasted by fixed-tile execution.
    pub padded_rows: AtomicU64,
    /// Requests shed by admission control (`try_score*` with the bounded
    /// request queue full).
    pub shed: AtomicU64,
    /// Scorer panics caught (injected faults and real scoring bugs). The
    /// worker survives every one — the pool never shrinks.
    pub scorer_panics: AtomicU64,
    /// Batches finalized as failed: every affected client received a typed
    /// error reply instead of hanging.
    pub failed_batches: AtomicU64,
    /// End-to-end request latency (enqueue → reply), log₂-bucketed µs.
    pub latency: LatencyHistogram,
    /// Fault-injection hook: shard jobs remaining to panic deliberately
    /// ([`ServerHandle::inject_scorer_panics`]).
    inject_faults: AtomicUsize,
    /// Fault-injection hook: artificial per-shard-job stall, microseconds
    /// ([`ServerHandle::inject_scorer_stall_ms`]).
    stall_us: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            queue_wait_us: AtomicU64::new(0),
            score_us: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            scorer_panics: AtomicU64::new(0),
            failed_batches: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
            inject_faults: AtomicUsize::new(0),
            stall_us: AtomicU64::new(0),
        }
    }
}

impl ServeMetrics {
    /// Fraction of submissions shed by admission control:
    /// `shed / (served + shed)`. 0 with no traffic.
    pub fn shed_rate(&self) -> f64 {
        let shed = self.shed.load(Ordering::Relaxed) as f64;
        let served = self.requests.load(Ordering::Relaxed) as f64;
        if shed + served == 0.0 {
            return 0.0;
        }
        shed / (shed + served)
    }

    /// Claim one injected fault, if any are pending (scorer workers call
    /// this per shard job and panic deliberately when it returns true).
    fn take_injected_fault(&self) -> bool {
        self.inject_faults
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
            .is_ok()
    }

    /// Mean queue wait per request, milliseconds.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        self.queue_wait_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }

    /// Mean batch occupancy (requests per batch).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Latency samples recorded so far (0 means the percentile accessors
    /// have nothing real to report — wire surfaces emit `null`).
    pub fn latency_samples(&self) -> u64 {
        self.latency.count()
    }

    /// End-to-end request latency percentile in milliseconds, `None` while
    /// idle (see [`LatencyHistogram::percentile`]).
    pub fn percentile(&self, p: f64) -> Option<f64> {
        self.latency.percentile(p)
    }

    /// Median end-to-end request latency, milliseconds.
    pub fn p50_ms(&self) -> f64 {
        self.latency.percentile_ms(50.0)
    }

    /// 95th-percentile end-to-end request latency, milliseconds.
    pub fn p95_ms(&self) -> f64 {
        self.latency.percentile_ms(95.0)
    }

    /// 99th-percentile end-to-end request latency, milliseconds.
    pub fn p99_ms(&self) -> f64 {
        self.latency.percentile_ms(99.0)
    }
}

/// The compiled plans the scorer workers execute: one sharded binary plan,
/// or one sharded plan per one-vs-rest class.
enum PlanSet {
    Binary(ShardedPlan),
    Multi(Vec<ShardedPlan>),
}

impl PlanSet {
    /// Accumulator classes (binary servers reduce one class).
    fn classes(&self) -> usize {
        match self {
            PlanSet::Binary(_) => 1,
            PlanSet::Multi(ps) => ps.len(),
        }
    }

    /// Shard jobs one batch fans out into.
    fn total_jobs(&self) -> usize {
        match self {
            PlanSet::Binary(p) => p.num_shards(),
            PlanSet::Multi(ps) => ps.iter().map(|p| p.num_shards()).sum(),
        }
    }
}

/// One batch shared between the shard scorer workers: request rows, reply
/// channels, and the class-major partial-sum accumulator
/// (`classes * rows.len()`; binary servers have one class). The last worker
/// to reduce its shard finalizes (metrics + replies).
struct BatchShared {
    rows: Vec<RowOwned>,
    replies: Vec<SyncSender<Reply>>,
    enqueued: Vec<Instant>,
    acc: Mutex<Vec<f64>>,
    pending: AtomicUsize,
    /// True when replies carry argmax + per-class margins.
    multiclass: bool,
    /// Set when any shard job of this batch panicked (or was dropped at
    /// shutdown): the partial sums are untrustworthy, so every client gets
    /// a typed [`Reply::Failed`] instead of a silently-wrong score.
    failed: AtomicBool,
    started: Instant,
    metrics: Arc<ServeMetrics>,
}

/// Lock a mutex even if a panicking scorer poisoned it. Only used where
/// the guarded data is either discarded (failed batches) or written by
/// panic-free code paths.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl BatchShared {
    fn finalize(&self) {
        let n = self.rows.len();
        if self.failed.load(Ordering::Acquire) {
            self.metrics.failed_batches.fetch_add(1, Ordering::Relaxed);
            let payload: Vec<Reply> = (0..n).map(|_| Reply::Failed).collect();
            deliver(payload, &self.replies, &self.enqueued, self.started, &self.metrics);
            return;
        }
        let scores = std::mem::take(&mut *lock_ignore_poison(&self.acc));
        let payload: Vec<Reply> = if self.multiclass {
            let classes = scores.len() / n.max(1);
            (0..n)
                .map(|i| {
                    let argmax = crate::infer::argmax_class(&scores, n, i);
                    let per_class = (0..classes).map(|c| scores[c * n + i]).collect();
                    Reply::Multi(MultiScore { argmax, scores: per_class })
                })
                .collect()
        } else {
            scores.into_iter().map(Reply::Score).collect()
        };
        deliver(payload, &self.replies, &self.enqueued, self.started, &self.metrics);
    }
}

/// Record batch metrics + per-request latency, then send the replies.
fn deliver(
    payload: Vec<Reply>,
    replies: &[SyncSender<Reply>],
    enqueued: &[Instant],
    started: Instant,
    metrics: &ServeMetrics,
) {
    metrics.requests.fetch_add(replies.len() as u64, Ordering::Relaxed);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.score_us.fetch_add(started.elapsed().as_micros() as u64, Ordering::Relaxed);
    for ((r, d), t) in replies.iter().zip(payload).zip(enqueued) {
        metrics.latency.record_us(t.elapsed().as_micros() as u64);
        let _ = r.send(d);
    }
}

/// One unit of scorer work: reduce shard `shard` of class `class`'s plan
/// over a whole batch (binary servers always dispatch class 0).
struct ShardJob {
    batch: Arc<BatchShared>,
    class: usize,
    shard: usize,
}

/// Handle to a running model server. Cloneable; stopping any handle (or
/// dropping them all) stops the runtime after the queue drains.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Arc<Mutex<Option<SyncSender<Request>>>>,
    metrics: Arc<ServeMetrics>,
    batcher: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
    cols: usize,
    /// `Some(K)` on multiclass servers, `None` on binary servers.
    classes: Option<usize>,
    /// Online learner attached by [`serve_online`]: the feedback path
    /// ([`ServerHandle::update`]) steps this learner; scoring keeps
    /// reading the immutable compiled plan from the last snapshot.
    online: Option<Arc<crate::online::OnlineSlot>>,
}

impl ServerHandle {
    /// Submit one dense feature row; blocks for the decision value.
    /// Binary servers only — multiclass servers answer
    /// [`ServerHandle::score_multiclass`].
    pub fn score(&self, x: &[f32]) -> Result<f64> {
        Ok(self.score_inner(x, false)?)
    }

    /// Admission-controlled [`ServerHandle::score`]: sheds with
    /// [`SubmitError::Overloaded`] (counted in [`ServeMetrics::shed`]) when
    /// the bounded request queue is full, instead of blocking the caller.
    pub fn try_score(&self, x: &[f32]) -> std::result::Result<f64, SubmitError> {
        self.score_inner(x, true)
    }

    /// Submit one CSR feature row (`indices` sorted strictly ascending,
    /// 0-based, parallel to `values`); blocks for the decision value.
    /// Requests are external input: the full CSR contract — including value
    /// finiteness — is validated here so a malformed request errors instead
    /// of panicking the runtime or poisoning the accumulator.
    pub fn score_sparse(&self, indices: &[u32], values: &[f32]) -> Result<f64> {
        Ok(self.score_sparse_inner(indices, values, false)?)
    }

    /// Admission-controlled [`ServerHandle::score_sparse`] (sheds when the
    /// request queue is full).
    pub fn try_score_sparse(
        &self,
        indices: &[u32],
        values: &[f32],
    ) -> std::result::Result<f64, SubmitError> {
        self.score_sparse_inner(indices, values, true)
    }

    /// Submit one dense feature row to a multiclass server; blocks for the
    /// argmax class index plus every class's one-vs-rest margin.
    pub fn score_multiclass(&self, x: &[f32]) -> Result<MultiScore> {
        Ok(self.score_multiclass_inner(x, false)?)
    }

    /// Admission-controlled [`ServerHandle::score_multiclass`] (sheds when
    /// the request queue is full).
    pub fn try_score_multiclass(&self, x: &[f32]) -> std::result::Result<MultiScore, SubmitError> {
        self.score_multiclass_inner(x, true)
    }

    /// [`ServerHandle::score_multiclass`] for a CSR request row (same
    /// validated CSR contract as [`ServerHandle::score_sparse`]).
    pub fn score_multiclass_sparse(&self, indices: &[u32], values: &[f32]) -> Result<MultiScore> {
        Ok(self.score_multiclass_sparse_inner(indices, values, false)?)
    }

    /// Admission-controlled [`ServerHandle::score_multiclass_sparse`]
    /// (sheds when the request queue is full).
    pub fn try_score_multiclass_sparse(
        &self,
        indices: &[u32],
        values: &[f32],
    ) -> std::result::Result<MultiScore, SubmitError> {
        self.score_multiclass_sparse_inner(indices, values, true)
    }

    fn score_inner(&self, x: &[f32], shed: bool) -> std::result::Result<f64, SubmitError> {
        self.expect_binary()?;
        binary_reply(self.submit(self.dense_row(x)?, shed)?)
    }

    fn score_sparse_inner(
        &self,
        indices: &[u32],
        values: &[f32],
        shed: bool,
    ) -> std::result::Result<f64, SubmitError> {
        self.expect_binary()?;
        binary_reply(self.submit(self.csr_row(indices, values)?, shed)?)
    }

    fn score_multiclass_inner(
        &self,
        x: &[f32],
        shed: bool,
    ) -> std::result::Result<MultiScore, SubmitError> {
        self.expect_multiclass()?;
        multi_reply(self.submit(self.dense_row(x)?, shed)?)
    }

    fn score_multiclass_sparse_inner(
        &self,
        indices: &[u32],
        values: &[f32],
        shed: bool,
    ) -> std::result::Result<MultiScore, SubmitError> {
        self.expect_multiclass()?;
        multi_reply(self.submit(self.csr_row(indices, values)?, shed)?)
    }

    /// Number of classes served (`None` for binary servers).
    pub fn n_classes(&self) -> Option<usize> {
        self.classes
    }

    /// Feature dimensionality this server scores.
    pub fn input_cols(&self) -> usize {
        self.cols
    }

    /// Fault injection: arrange for the next `n` shard jobs executed by
    /// this server's scorers to panic deliberately. Tests and the remote
    /// serve bench use this to prove a dying scorer fails its batch typed
    /// ([`SubmitError::Failed`]) instead of hanging clients, and that the
    /// worker pool survives ([`ServeMetrics::scorer_panics`] counts).
    pub fn inject_scorer_panics(&self, n: usize) {
        self.metrics.inject_faults.fetch_add(n, Ordering::SeqCst);
    }

    /// Fault injection: stall every shard job by `ms` milliseconds (0
    /// clears). Makes overload and backpressure deterministic in tests —
    /// a slow scorer fills the bounded queues on demand.
    pub fn inject_scorer_stall_ms(&self, ms: u64) {
        self.metrics.stall_us.store(ms.saturating_mul(1000), Ordering::SeqCst);
    }

    fn expect_binary(&self) -> std::result::Result<(), SubmitError> {
        match self.classes {
            None => Ok(()),
            Some(_) => Err(SubmitError::Invalid("multiclass server: use score_multiclass".into())),
        }
    }

    fn expect_multiclass(&self) -> std::result::Result<(), SubmitError> {
        match self.classes {
            Some(_) => Ok(()),
            None => Err(SubmitError::Invalid("binary server: use score/score_sparse".into())),
        }
    }

    /// Validate and own a dense request row (dimension + finiteness — one
    /// NaN would silently poison the whole batch's shared accumulator).
    fn dense_row(&self, x: &[f32]) -> std::result::Result<RowOwned, SubmitError> {
        if x.len() != self.cols {
            let msg = format!("expected {} features, got {}", self.cols, x.len());
            return Err(SubmitError::Invalid(msg));
        }
        if let Some(i) = x.iter().position(|v| !v.is_finite()) {
            let msg = format!("non-finite feature value at index {i}");
            return Err(SubmitError::Invalid(msg));
        }
        Ok(RowOwned::Dense(x.to_vec()))
    }

    /// Validate the external CSR request contract (lengths, range, order,
    /// finiteness) and own the row.
    fn csr_row(
        &self,
        indices: &[u32],
        values: &[f32],
    ) -> std::result::Result<RowOwned, SubmitError> {
        if indices.len() != values.len() {
            return Err(SubmitError::Invalid("indices/values length mismatch".into()));
        }
        let mut prev: Option<u32> = None;
        for (&i, &v) in indices.iter().zip(values) {
            if (i as usize) >= self.cols {
                let msg = format!("feature index {i} out of range ({} cols)", self.cols);
                return Err(SubmitError::Invalid(msg));
            }
            if let Some(p) = prev {
                if i <= p {
                    let msg = "indices must be sorted strictly ascending";
                    return Err(SubmitError::Invalid(msg.into()));
                }
            }
            prev = Some(i);
            if !v.is_finite() {
                let msg = format!("non-finite feature value at index {i}");
                return Err(SubmitError::Invalid(msg));
            }
        }
        Ok(RowOwned::Sparse { indices: indices.to_vec(), values: values.to_vec(), cols: self.cols })
    }

    /// Queue one validated row and block for its reply. `shed: true` is the
    /// admission-control mode: a full request queue returns
    /// [`SubmitError::Overloaded`] immediately instead of blocking.
    fn submit(&self, x: RowOwned, shed: bool) -> std::result::Result<Reply, SubmitError> {
        let tx = match self.tx.lock().unwrap().as_ref() {
            Some(tx) => tx.clone(),
            None => return Err(SubmitError::Stopped),
        };
        let (rtx, rrx) = sync_channel(1);
        let req = Request { x, reply: rtx, enqueued: Instant::now() };
        if shed {
            use std::sync::mpsc::TrySendError;
            match tx.try_send(req) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    return Err(SubmitError::Overloaded);
                }
                Err(TrySendError::Disconnected(_)) => return Err(SubmitError::Stopped),
            }
        } else {
            tx.send(req).map_err(|_| SubmitError::Stopped)?;
        }
        drop(tx);
        match rrx.recv() {
            Ok(Reply::Failed) => Err(SubmitError::Failed),
            Ok(reply) => Ok(reply),
            Err(_) => Err(SubmitError::Stopped),
        }
    }

    /// Submit one row, returning the predicted label (binary servers).
    pub fn predict(&self, x: &[f32]) -> Result<f32> {
        Ok(if self.score(x)? >= 0.0 { 1.0 } else { -1.0 })
    }

    /// Apply one `(row, label)` feedback example to the attached online
    /// learner (servers started with [`serve_online`]; others answer
    /// [`SubmitError::Invalid`]). Validation mirrors the scoring path:
    /// dimension + finiteness on `x`, `y ∈ {−1, +1}`. Returns the total
    /// update count after this example. Scoring requests are *not*
    /// affected until the next snapshot — see the consistency contract in
    /// [`crate::online`].
    pub fn update(&self, x: &[f32], y: f32) -> std::result::Result<u64, SubmitError> {
        let slot = match &self.online {
            Some(s) => s,
            None => {
                return Err(SubmitError::Invalid(
                    "server has no online learner attached (start with serve_online)".into(),
                ))
            }
        };
        // Reuse the dense request validation (dimension + finiteness).
        self.dense_row(x)?;
        if y != 1.0 && y != -1.0 {
            return Err(SubmitError::Invalid(format!("label must be ±1, got {y}")));
        }
        let (_d, seen) = slot.update_dense(x, y);
        Ok(seen)
    }

    /// The attached online learner, if this server was started with
    /// [`serve_online`] (registries share this slot across snapshot
    /// hot-swaps so no update is lost in transit).
    pub fn online_slot(&self) -> Option<&Arc<crate::online::OnlineSlot>> {
        self.online.as_ref()
    }

    /// True until [`ServerHandle::stop`] ran (on any clone of this handle).
    pub fn is_running(&self) -> bool {
        self.tx.lock().unwrap().is_some()
    }

    /// Serving metrics snapshot access.
    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Stop the runtime: drops the request sender so the batcher exits the
    /// moment the queue drains (`Disconnected`, no poll timeout), then
    /// joins the batcher thread — which has already closed the shard-job
    /// queue and joined every scorer worker. On return, all server threads
    /// are gone and every in-flight request has been answered.
    pub fn stop(&self) {
        self.tx.lock().unwrap().take();
        let batcher = self.batcher.lock().unwrap().take();
        if let Some(h) = batcher {
            let _ = h.join();
        }
    }
}

/// Unwrap a binary decision reply ([`Reply::Failed`] is already mapped by
/// `submit`; a multiclass reply here is a runtime invariant violation).
fn binary_reply(r: Reply) -> std::result::Result<f64, SubmitError> {
    match r {
        Reply::Score(d) => Ok(d),
        _ => Err(SubmitError::Invalid("unexpected multiclass reply".into())),
    }
}

/// Unwrap a multiclass reply.
fn multi_reply(r: Reply) -> std::result::Result<MultiScore, SubmitError> {
    match r {
        Reply::Multi(m) => Ok(m),
        _ => Err(SubmitError::Invalid("unexpected binary reply".into())),
    }
}

/// Start a server for `model`: validates `cfg`, compiles the sharded
/// scoring plan, and spawns the batcher plus `cfg.workers` scorer threads.
pub fn serve(model: OdmModel, backend: Backend, cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    let cols = model.input_cols();
    let precision = cfg.precision.unwrap_or_default();
    let plan =
        Arc::new(PlanSet::Binary(ShardedPlan::compile_with(&model, cfg.shards, precision)));
    // The model itself is only needed for the PJRT tile dispatch; native
    // servers score exclusively through the compiled plan, so don't keep a
    // second copy of the support vectors alive.
    let model = match &backend {
        Backend::Xla(_) => Some(model),
        Backend::Native => None,
    };
    spawn_runtime(model, backend, plan, cfg, cols, None)
}

/// Start a binary server for an online learner: compiles the scoring plan
/// from the slot's *current* snapshot and attaches the slot so
/// [`ServerHandle::update`] can apply feedback. The running plan is
/// immutable — updates accumulate in the learner and become visible to
/// scoring when the owner (typically [`crate::net::ModelRegistry`])
/// snapshots and swaps in a fresh server. Native backend only: online
/// snapshots are plain linear models.
pub fn serve_online(
    slot: Arc<crate::online::OnlineSlot>,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    let model = slot.snapshot_model();
    let mut handle = serve(model, Backend::Native, cfg)?;
    handle.online = Some(slot);
    Ok(handle)
}

/// Start a multiclass server: one sharded plan per one-vs-rest class, each
/// batch fanned out as one shard job per `(class, shard)` pair across the
/// same scorer worker pool. Requests go through
/// [`ServerHandle::score_multiclass`] / `score_multiclass_sparse` and come
/// back as argmax + per-class margins. Native scoring only (per-class
/// kernel expansions have no PJRT tile layout).
pub fn serve_multiclass(model: MulticlassModel, cfg: ServeConfig) -> Result<ServerHandle> {
    cfg.validate()?;
    crate::ensure!(model.n_classes() >= 2, "multiclass serving needs >= 2 classes");
    let cols = model.input_cols();
    let classes = model.n_classes();
    let precision = cfg.precision.unwrap_or_default();
    let plans: Vec<ShardedPlan> = model
        .models
        .iter()
        .map(|m| ShardedPlan::compile_with(m, cfg.shards, precision))
        .collect();
    for p in &plans {
        crate::ensure!(p.input_cols() == cols, "class models must share input dims");
    }
    let plan = Arc::new(PlanSet::Multi(plans));
    spawn_runtime(None, Backend::Native, plan, cfg, cols, Some(classes))
}

/// Spawn the shared runtime: `cfg.workers` scorer threads draining the
/// shard-job queue plus the batcher (which owns shutdown of both).
fn spawn_runtime(
    model: Option<OdmModel>,
    backend: Backend,
    plan: Arc<PlanSet>,
    cfg: ServeConfig,
    cols: usize,
    classes: Option<usize>,
) -> Result<ServerHandle> {
    let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
    let metrics = Arc::new(ServeMetrics::default());
    // Bounded shard-job queue: the batcher pipelines at most ~4 batches of
    // jobs ahead of the scorers, then blocks — which backs pressure up into
    // the bounded request queue. Memory under overload is O(queue_depth +
    // 4 batches), not O(however far the batcher outran the scorers).
    let job_cap = plan.total_jobs().max(cfg.workers).saturating_mul(4);
    let queue: Arc<WorkQueue<ShardJob>> = Arc::new(WorkQueue::bounded(job_cap));
    let mut scorers = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        let plan = Arc::clone(&plan);
        let queue = Arc::clone(&queue);
        scorers.push(
            std::thread::Builder::new()
                .name(format!("sodm-scorer-{w}"))
                .spawn(move || scorer_loop(plan, queue))
                .expect("spawn scorer"),
        );
    }
    let batcher = {
        let metrics = Arc::clone(&metrics);
        std::thread::Builder::new()
            .name("sodm-batcher".into())
            .spawn(move || batcher_loop(model, backend, plan, cfg, rx, queue, metrics, scorers))
            .expect("spawn batcher")
    };
    Ok(ServerHandle {
        tx: Arc::new(Mutex::new(Some(tx))),
        metrics,
        batcher: Arc::new(Mutex::new(Some(batcher))),
        cols,
        classes,
        online: None,
    })
}

/// RAII completion guard for one shard job: dropping it decrements the
/// batch's `pending` count — *including during a panic unwind* — so the
/// last shard always finalizes and clients always get a reply. A drop
/// during unwind first marks the batch failed (typed error replies) and
/// counts the panic.
struct JobGuard {
    batch: Arc<BatchShared>,
}

impl Drop for JobGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.batch.failed.store(true, Ordering::Release);
            self.batch.metrics.scorer_panics.fetch_add(1, Ordering::Relaxed);
        }
        if self.batch.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.batch.finalize();
        }
    }
}

/// Scorer worker: drain shard jobs until the queue closes. Each job scores
/// one SV shard of one class's plan over a whole batch and adds the partial
/// sums into the batch's class-major accumulator; the worker that retires
/// the last shard finalizes. Jobs run under a [`JobGuard`] inside
/// `catch_unwind`: a panicking `score_block` fails the batch typed and the
/// worker thread survives (the pool never shrinks — with `workers: 1` a
/// lost thread used to deadlock every future client).
fn scorer_loop(plan: Arc<PlanSet>, queue: Arc<WorkQueue<ShardJob>>) {
    while let Some(job) = queue.pop() {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = JobGuard { batch: Arc::clone(&job.batch) };
            let stall = job.batch.metrics.stall_us.load(Ordering::Relaxed);
            if stall > 0 {
                std::thread::sleep(Duration::from_micros(stall));
            }
            if job.batch.metrics.take_injected_fault() {
                panic!("injected scorer fault");
            }
            run_shard_job(&plan, &job);
        }));
        // The guard already marked the batch failed and counted the panic;
        // dropping the payload here is what keeps the worker alive.
        drop(outcome);
    }
}

/// The compute of one shard job (panic-isolated by [`scorer_loop`]).
fn run_shard_job(plan: &PlanSet, job: &ShardJob) {
    let rows: Vec<RowRef> = job.batch.rows.iter().map(|r| r.as_row_ref()).collect();
    let n = rows.len();
    let shard_plan = match plan {
        PlanSet::Binary(p) => p.shard(job.shard),
        PlanSet::Multi(ps) => ps[job.class].shard(job.shard),
    };
    let mut partial = vec![0.0f64; n];
    shard_plan.score_block(&rows, &mut partial);
    let mut acc = lock_ignore_poison(&job.batch.acc);
    let base = job.class * n;
    for (a, p) in acc[base..base + n].iter_mut().zip(&partial) {
        *a += p;
    }
}

fn batcher_loop(
    model: Option<OdmModel>,
    backend: Backend,
    plan: Arc<PlanSet>,
    cfg: ServeConfig,
    rx: Receiver<Request>,
    queue: Arc<WorkQueue<ShardJob>>,
    metrics: Arc<ServeMetrics>,
    scorers: Vec<std::thread::JoinHandle<()>>,
) {
    let mut batch: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    loop {
        // Block for the first request; `Err` means every sender is gone
        // (stop() or all handles dropped) and the queue has drained.
        match rx.recv() {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
        // Fill the batch up to max_batch or max_wait.
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        dispatch_batch(model.as_ref(), &backend, &plan, &mut batch, &queue, &metrics);
    }
    queue.close();
    for s in scorers {
        let _ = s.join();
    }
}

/// Route one assembled batch: PJRT tile path when available, otherwise one
/// shard job per (class, shard) pair onto the scorer queue (the batcher
/// moves on to the next batch immediately — batches pipeline through the
/// workers).
fn dispatch_batch(
    model: Option<&OdmModel>,
    backend: &Backend,
    plan: &Arc<PlanSet>,
    batch: &mut Vec<Request>,
    queue: &Arc<WorkQueue<ShardJob>>,
    metrics: &Arc<ServeMetrics>,
) {
    let n = batch.len();
    if n == 0 {
        return;
    }
    for r in batch.iter() {
        let waited = r.enqueued.elapsed().as_micros() as u64;
        metrics.queue_wait_us.fetch_add(waited, Ordering::Relaxed);
    }
    let started = Instant::now();
    if let (Backend::Xla(engine), Some(model)) = (backend, model) {
        if let Some(decisions) = xla_batch_decisions(model, engine, batch, metrics) {
            let (_, replies, enqueued) = split_requests(batch);
            let payload: Vec<Reply> = decisions.into_iter().map(Reply::Score).collect();
            deliver(payload, &replies, &enqueued, started, metrics);
            return;
        }
    }
    let (rows, replies, enqueued) = split_requests(batch);
    let shared = Arc::new(BatchShared {
        rows,
        replies,
        enqueued,
        acc: Mutex::new(vec![0.0; plan.classes() * n]),
        pending: AtomicUsize::new(plan.total_jobs()),
        multiclass: matches!(&**plan, PlanSet::Multi(_)),
        failed: AtomicBool::new(false),
        started,
        metrics: Arc::clone(metrics),
    });
    // A refused push (queue closed mid-shutdown) still retires the job's
    // pending slot, so the batch finalizes (failed) instead of leaking its
    // reply channels.
    let push_job = |job: ShardJob| {
        let batch = Arc::clone(&job.batch);
        if !queue.push(job) {
            batch.failed.store(true, Ordering::Release);
            if batch.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                batch.finalize();
            }
        }
    };
    match &**plan {
        PlanSet::Binary(p) => {
            for s in 0..p.num_shards() {
                push_job(ShardJob { batch: Arc::clone(&shared), class: 0, shard: s });
            }
        }
        PlanSet::Multi(ps) => {
            for (c, p) in ps.iter().enumerate() {
                for s in 0..p.num_shards() {
                    push_job(ShardJob { batch: Arc::clone(&shared), class: c, shard: s });
                }
            }
        }
    }
}

/// Drain the batch into parallel row/reply/enqueue vectors, keeping the
/// batcher's reusable `Vec<Request>` allocation alive across batches.
fn split_requests(
    batch: &mut Vec<Request>,
) -> (Vec<RowOwned>, Vec<SyncSender<Reply>>, Vec<Instant>) {
    let mut rows = Vec::with_capacity(batch.len());
    let mut replies = Vec::with_capacity(batch.len());
    let mut enqueued = Vec::with_capacity(batch.len());
    for r in batch.drain(..) {
        rows.push(r.x);
        replies.push(r.reply);
        enqueued.push(r.enqueued);
    }
    (rows, replies, enqueued)
}

/// Score a batch through the PJRT artifacts if the model has a tile
/// layout. `None` routes the batch to the native sharded plan (no layout,
/// or the PJRT dispatch failed).
fn xla_batch_decisions(
    model: &OdmModel,
    engine: &XlaEngine,
    batch: &[Request],
    metrics: &ServeMetrics,
) -> Option<Vec<f64>> {
    let n = batch.len();
    let cols = model.input_cols();
    // PJRT artifacts consume dense row-major tiles: scatter every request
    // row into a batch buffer — built only by the arms that actually
    // dispatch, so natively-scored models never pay the densification.
    let build_xt = || {
        let mut xt = vec![0.0f32; n * cols];
        for (r, chunk) in batch.iter().zip(xt.chunks_mut(cols)) {
            r.x.as_row_ref().scatter_into(chunk);
        }
        xt
    };
    let res = match model {
        OdmModel::Linear { w } => engine.linear_decisions(w, &build_xt(), cols),
        OdmModel::Kernel { kernel: KernelKind::Rbf { gamma }, sv_x, coef, cols: mcols } => {
            engine.rbf_decisions(sv_x, coef, &build_xt(), *mcols, *gamma)
        }
        // Linear-kernel expansions and CSR support vectors have no PJRT
        // tile layout — the sharded native plan scores them.
        _ => return None,
    };
    match res {
        Ok(d) => {
            let tile = engine.geometry.dec_b;
            let padded = n.div_ceil(tile) * tile - n;
            metrics.padded_rows.fetch_add(padded as u64, Ordering::Relaxed);
            Some(d)
        }
        Err(e) => {
            eprintln!("serve: PJRT batch failed ({e:#}); native fallback");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::infer::ScoringPlan;
    use crate::odm::{train_exact_odm, OdmParams};
    use crate::qp::SolveBudget;

    fn model() -> (OdmModel, crate::data::Dataset) {
        let mut s = SynthSpec::named("svmguide1", 0.01, 3);
        s.rows = 120;
        let ds = s.generate();
        let m = train_exact_odm(
            &ds,
            &KernelKind::Rbf { gamma: 1.0 },
            &OdmParams::default(),
            &SolveBudget::default(),
        );
        (m, ds)
    }

    fn linear_model() -> OdmModel {
        OdmModel::Linear { w: vec![0.5, -1.0, 0.25, 0.0, 2.0] }
    }

    fn one_worker() -> ServeConfig {
        ServeConfig {
            workers: 1,
            shards: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        }
    }

    #[test]
    fn native_serving_matches_plan() {
        let (m, ds) = model();
        let plan = ScoringPlan::compile(&m);
        let direct: Vec<f64> = (0..10).map(|i| plan.score_rr(RowRef::Dense(ds.row(i)))).collect();
        let reference: Vec<f64> = (0..10).map(|i| m.decision(ds.row(i))).collect();
        let h = serve(m, Backend::Native, ServeConfig::default()).unwrap();
        for i in 0..10 {
            let got = h.score(ds.row(i)).unwrap();
            // shard-reduce regroups f64 sums vs the single-threaded plan…
            assert!((got - direct[i]).abs() < 1e-9 * (1.0 + direct[i].abs()));
            // …and the plan itself tracks the scalar reference at 1e-6.
            let r = reference[i];
            assert!((got - r).abs() < 1e-6 * (1.0 + r.abs()), "row {i}: {got} vs {r}");
        }
        h.stop();
    }

    #[test]
    fn batcher_coalesces_concurrent_requests() {
        let (m, ds) = model();
        let h = serve(
            m,
            Backend::Native,
            ServeConfig { max_wait: Duration::from_millis(20), ..Default::default() },
        )
        .unwrap();
        std::thread::scope(|s| {
            for t in 0..16 {
                let h = h.clone();
                let row = ds.row(t % ds.rows).to_vec();
                s.spawn(move || {
                    for _ in 0..8 {
                        h.score(&row).unwrap();
                    }
                });
            }
        });
        let reqs = h.metrics().requests.load(Ordering::Relaxed);
        let batches = h.metrics().batches.load(Ordering::Relaxed);
        assert_eq!(reqs, 128);
        assert!(batches < reqs, "batching should coalesce: {batches} batches");
        h.stop();
    }

    #[test]
    fn wrong_dim_rejected() {
        let (m, _) = model();
        let h = serve(m, Backend::Native, ServeConfig::default()).unwrap();
        assert!(h.score(&[0.0]).is_err());
        h.stop();
    }

    #[test]
    fn predict_sign() {
        let h = serve(
            OdmModel::Linear { w: vec![1.0, -1.0] },
            Backend::Native,
            ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(h.predict(&[1.0, 0.0]).unwrap(), 1.0);
        assert_eq!(h.predict(&[0.0, 1.0]).unwrap(), -1.0);
        h.stop();
    }

    #[test]
    fn sparse_requests_match_plan_decisions() {
        let spec = crate::data::sparse::SparseSynthSpec::new(100, 200, 0.05, 5);
        let sp = spec.generate();
        let m = crate::odm::train_exact_odm(
            &sp,
            &KernelKind::Rbf { gamma: 0.5 },
            &OdmParams::default(),
            &SolveBudget { max_sweeps: 20, ..SolveBudget::default() },
        );
        assert!(matches!(m, crate::odm::OdmModel::SparseKernel { .. }));
        let reference: Vec<f64> = (0..8).map(|i| m.decision_rr(sp.row_ref(i))).collect();
        let h = serve(m, Backend::Native, ServeConfig::default()).unwrap();
        for (i, want) in reference.iter().enumerate() {
            let (lo, hi) = (sp.indptr[i], sp.indptr[i + 1]);
            let got = h.score_sparse(&sp.indices[lo..hi], &sp.values[lo..hi]).unwrap();
            assert!((got - want).abs() < 1e-6 * (1.0 + want.abs()), "row {i}: {got} vs {want}");
        }
        h.stop();
    }

    #[test]
    fn sparse_request_rejects_out_of_range_index() {
        let h = serve(
            OdmModel::Linear { w: vec![1.0, -1.0, 0.5] },
            Backend::Native,
            ServeConfig::default(),
        )
        .unwrap();
        assert!(h.score_sparse(&[0, 5], &[1.0, 1.0]).is_err());
        assert!((h.score_sparse(&[0, 2], &[1.0, 2.0]).unwrap() - 2.0).abs() < 1e-12);
        h.stop();
    }

    #[test]
    fn metrics_accumulate_with_latency() {
        let (m, ds) = model();
        let h = serve(m, Backend::Native, ServeConfig::default()).unwrap();
        for i in 0..5 {
            h.score(ds.row(i)).unwrap();
        }
        let m = h.metrics();
        assert_eq!(m.requests.load(Ordering::Relaxed), 5);
        assert!(m.mean_batch_size() >= 1.0);
        assert_eq!(m.latency.count(), 5, "every request records a latency sample");
        assert!(m.p50_ms() > 0.0);
        assert!(m.p50_ms() <= m.p95_ms() && m.p95_ms() <= m.p99_ms());
        h.stop();
    }

    #[test]
    fn config_validation_is_typed_and_checked_at_serve_time() {
        let bad = [
            (ServeConfig { max_batch: 0, ..Default::default() }, ConfigError::ZeroMaxBatch),
            (ServeConfig { queue_depth: 0, ..Default::default() }, ConfigError::ZeroQueueDepth),
            (ServeConfig { workers: 0, ..Default::default() }, ConfigError::ZeroWorkers),
            (ServeConfig { shards: 0, ..Default::default() }, ConfigError::ZeroShards),
        ];
        let (m, _) = model();
        for (cfg, want) in bad {
            assert_eq!(cfg.validate().unwrap_err(), want);
            assert!(serve(m.clone(), Backend::Native, cfg).is_err());
        }
        assert!(ServeConfig::default().validate().is_ok());
    }

    #[test]
    fn zero_max_wait_is_valid() {
        let (m, ds) = model();
        let cfg = ServeConfig { max_wait: Duration::ZERO, ..Default::default() };
        let h = serve(m, Backend::Native, cfg).unwrap();
        for i in 0..4 {
            let _ = h.score(ds.row(i)).unwrap();
        }
        assert_eq!(h.metrics().requests.load(Ordering::Relaxed), 4);
        h.stop();
    }

    #[test]
    fn stop_joins_runtime_and_refuses_new_requests() {
        let (m, ds) = model();
        let h = serve(m, Backend::Native, ServeConfig::default()).unwrap();
        h.score(ds.row(0)).unwrap();
        let t0 = Instant::now();
        h.stop();
        // Sender-drop shutdown: no 50 ms poll loop to wait out. The bound
        // is generous for CI noise; the point is "joined promptly".
        assert!(t0.elapsed() < Duration::from_secs(2), "stop took {:?}", t0.elapsed());
        assert!(h.score(ds.row(0)).is_err(), "requests after stop must error");
        h.stop(); // idempotent
    }

    #[test]
    fn non_finite_request_features_rejected_typed() {
        let h = serve(
            OdmModel::Linear { w: vec![1.0, -1.0] },
            Backend::Native,
            ServeConfig::default(),
        )
        .unwrap();
        assert!(h.score(&[f32::NAN, 0.0]).is_err());
        assert!(h.score(&[0.0, f32::INFINITY]).is_err());
        let e = h.try_score(&[f32::NEG_INFINITY, 0.0]).unwrap_err();
        assert!(matches!(e, SubmitError::Invalid(_)), "typed invalid, got {e:?}");
        let e = h.try_score_sparse(&[1], &[f32::NAN]).unwrap_err();
        assert!(matches!(e, SubmitError::Invalid(_)), "typed invalid, got {e:?}");
        // Finite requests around the rejects still score normally.
        assert!((h.score(&[1.0, 0.0]).unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(h.metrics().requests.load(Ordering::Relaxed), 1);
        h.stop();
    }

    #[test]
    fn scorer_panic_fails_batch_typed_and_pool_survives() {
        let cfg = ServeConfig { workers: 1, shards: 1, ..ServeConfig::default() };
        let h = serve(OdmModel::Linear { w: vec![2.0, 0.0] }, Backend::Native, cfg).unwrap();
        h.inject_scorer_panics(1);
        let e = h.try_score(&[1.0, 1.0]).unwrap_err();
        assert!(matches!(e, SubmitError::Failed), "typed batch failure, got {e:?}");
        let m = h.metrics();
        assert_eq!(m.scorer_panics.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed_batches.load(Ordering::Relaxed), 1);
        // The lone worker survived the panic — a dead thread here used to
        // deadlock every future request.
        assert!((h.score(&[1.0, 1.0]).unwrap() - 2.0).abs() < 1e-12);
        h.stop();
        assert!(matches!(h.try_score(&[1.0, 1.0]), Err(SubmitError::Stopped)));
    }

    #[test]
    fn overload_sheds_typed_instead_of_blocking() {
        let cfg = ServeConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_depth: 1,
            workers: 1,
            shards: 1,
        };
        let h = serve(OdmModel::Linear { w: vec![1.0, 0.0] }, Backend::Native, cfg).unwrap();
        h.inject_scorer_stall_ms(60);
        std::thread::scope(|s| {
            // Fill the whole pipeline: one stalled job executing, a full
            // shard-job queue, the batcher's in-hand batch, and the bounded
            // request queue; blocking submitters park behind all of it.
            for _ in 0..8 {
                let h = h.clone();
                s.spawn(move || assert!((h.score(&[1.0, 0.0]).unwrap() - 1.0).abs() < 1e-12));
            }
            std::thread::sleep(Duration::from_millis(30));
            let e = h.try_score(&[1.0, 0.0]).unwrap_err();
            assert!(matches!(e, SubmitError::Overloaded), "typed shed, got {e:?}");
            assert_eq!(h.metrics().shed.load(Ordering::Relaxed), 1);
            h.inject_scorer_stall_ms(0); // drain the backlog fast
        });
        assert_eq!(h.metrics().requests.load(Ordering::Relaxed), 8);
        assert!(h.metrics().shed_rate() > 0.0);
        h.stop();
    }

    use crate::multiclass::{train_ovr, MulticlassDataset, MulticlassModel, MulticlassSynthSpec};

    fn multiclass_model() -> (MulticlassModel, MulticlassDataset) {
        let ds = MulticlassSynthSpec::new(3, 90, 5, 21).generate();
        let run = train_ovr(
            &ds,
            &KernelKind::Rbf { gamma: 0.1 },
            &OdmParams::default(),
            &crate::multiclass::OvrConfig {
                budget: SolveBudget { max_sweeps: 15, ..SolveBudget::default() },
                ..Default::default()
            },
        );
        (run.model, ds)
    }

    #[test]
    fn multiclass_serving_matches_offline_plan() {
        let (m, ds) = multiclass_model();
        let plan = m.compile();
        let cfg = ServeConfig { workers: 3, shards: 2, ..ServeConfig::default() };
        let h = serve_multiclass(m, cfg).unwrap();
        assert_eq!(h.n_classes(), Some(3));
        let rows = ds.as_rows();
        let want_pred = plan.predict_rows(rows, 2);
        let want_scores = plan.score_rows(rows, 2);
        let n = ds.rows();
        for i in 0..12 {
            let got = h.score_multiclass(rows.row(i)).unwrap();
            assert_eq!(got.argmax, want_pred[i], "row {i}");
            for (c, s) in got.scores.iter().enumerate() {
                let w = want_scores[c * n + i];
                assert!((s - w).abs() < 1e-9 * (1.0 + w.abs()), "row {i} class {c}");
            }
        }
        h.stop();
    }

    #[test]
    fn multiclass_and_binary_servers_reject_each_others_requests() {
        let (mm, ds) = multiclass_model();
        let h = serve_multiclass(mm, ServeConfig::default()).unwrap();
        assert!(h.score(ds.as_rows().row(0)).is_err(), "binary request on multiclass server");
        assert!(h.score_sparse(&[0], &[1.0]).is_err());
        h.stop();
        let (bm, bds) = model();
        let hb = serve(bm, Backend::Native, ServeConfig::default()).unwrap();
        assert_eq!(hb.n_classes(), None);
        assert!(hb.score_multiclass(bds.row(0)).is_err(), "multiclass request on binary server");
        assert!(hb.score_multiclass_sparse(&[0], &[1.0]).is_err());
        hb.stop();
    }

    #[test]
    fn multiclass_sparse_requests_match_dense() {
        let (m, ds) = multiclass_model();
        let sp = ds.to_sparse();
        let cfg = ServeConfig { workers: 2, shards: 3, ..ServeConfig::default() };
        let h = serve_multiclass(m, cfg).unwrap();
        let crate::data::libsvm::LoadedDataset::Sparse(csr) = &sp.data else { unreachable!() };
        for i in 0..10 {
            let dense = h.score_multiclass(ds.as_rows().row(i)).unwrap();
            let (lo, hi) = (csr.indptr[i], csr.indptr[i + 1]);
            let sparse =
                h.score_multiclass_sparse(&csr.indices[lo..hi], &csr.values[lo..hi]).unwrap();
            assert_eq!(dense.argmax, sparse.argmax, "row {i}");
            // cross-backing (dense dot vs CSR gather) agreement is bounded
            // by f32 summation-order roundoff: the 1e-6 contract, not 1e-9
            for (a, b) in dense.scores.iter().zip(&sparse.scores) {
                assert!((a - b).abs() < 1e-6 * (1.0 + b.abs()), "row {i}");
            }
        }
        h.stop();
    }

    #[test]
    fn latency_histogram_percentiles() {
        let hist = LatencyHistogram::new();
        // Idle histograms have no latency to report: the Option form says
        // so, the flattened form reads 0 — never the old phantom ~1 µs
        // first-bucket bound.
        assert_eq!(hist.percentile(50.0), None);
        assert_eq!(hist.percentile(99.0), None);
        assert_eq!(hist.percentile_ms(50.0), 0.0);
        for _ in 0..99 {
            hist.record_us(100); // bucket [64, 128) µs
        }
        hist.record_us(1 << 20); // one ~1 s outlier
        assert_eq!(hist.count(), 100);
        assert!(hist.percentile(50.0).is_some());
        assert!(hist.percentile_ms(50.0) <= 0.128 + 1e-12);
        assert!(hist.percentile_ms(99.0) <= 0.128 + 1e-12);
        assert!(hist.percentile_ms(100.0) >= 1000.0);
    }

    #[test]
    fn idle_server_metrics_report_no_phantom_latency() {
        let h = serve(linear_model(), Backend::Native, one_worker()).unwrap();
        assert_eq!(h.metrics().latency_samples(), 0);
        assert_eq!(h.metrics().percentile(50.0), None);
        assert_eq!(h.metrics().p99_ms(), 0.0);
        h.stop();
    }

    #[test]
    fn online_server_updates_then_reswaps_fresh_snapshot() {
        use crate::online::{DriftStream, OnlineOdm, OnlineSlot};
        let params = crate::odm::OdmParams { lambda: 8.0, theta: 0.2, upsilon: 0.5 };
        let slot = Arc::new(OnlineSlot::new(OnlineOdm::new(5, params, 0.05).unwrap()));
        let h = serve_online(Arc::clone(&slot), one_worker()).unwrap();
        // A fresh learner scores 0 everywhere; the plan is a valid server.
        assert_eq!(h.score(&[1.0; 5]).unwrap(), 0.0);
        assert!(h.online_slot().is_some());
        // Feedback flows through the handle; scoring stays on the old
        // (immutable) snapshot until a new server is compiled.
        let mut stream = DriftStream::new(5, u64::MAX, 21);
        let mut last = 0;
        for _ in 0..200 {
            let (x, y) = stream.next_example();
            last = h.update(&x, y).unwrap();
        }
        assert_eq!(last, 200);
        assert_eq!(h.score(&[1.0; 5]).unwrap(), 0.0, "plan must be snapshot-isolated");
        // Dimension/label/attachment validation on the feedback path.
        assert!(matches!(h.update(&[1.0; 4], 1.0), Err(SubmitError::Invalid(_))));
        assert!(matches!(h.update(&[1.0; 5], 0.5), Err(SubmitError::Invalid(_))));
        h.stop();
        // Re-serve from the live slot: the updated weights now score.
        let h2 = serve_online(Arc::clone(&slot), one_worker()).unwrap();
        let (x, _) = stream.next_example();
        let d = h2.score(&x).unwrap();
        assert!(d.is_finite() && d != 0.0);
        h2.stop();
        let plain = serve(linear_model(), Backend::Native, one_worker()).unwrap();
        assert!(matches!(plain.update(&[1.0; 4], 1.0), Err(SubmitError::Invalid(_))));
        plain.stop();
    }
}
