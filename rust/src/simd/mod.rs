//! One vectorized numeric core under every hot path (ROADMAP item 4).
//!
//! Every dense inner loop in the crate — the kernel dots
//! ([`crate::kernel::dot`] / [`crate::kernel::sq_dist`]), the DCD margin
//! dots ([`crate::qp`]), the linear-collapse axpy ([`crate::infer`],
//! [`crate::api`]), and the RFF lift `Wx` product ([`crate::featmap`]) —
//! funnels through the micro-kernels here, so there is exactly one place to
//! vectorize and exactly one accumulation contract to test. The historical
//! per-module copies (the `api/mod.rs` chunks_exact loop, `qp::dot_f64`,
//! the `featmap` lift loop) are deleted; their summation orders live on in
//! [`scalar`].
//!
//! Two implementations sit behind each public function:
//!
//! * **[`scalar`]** (default, stable toolchain) — the hand-unrolled 4-lane
//!   loops, bit-identical to the historical copies they replaced (pinned by
//!   the tests below), so the default build's scores do not move.
//! * **vector** (`--features simd`, nightly `std::simd`) — explicit
//!   portable-SIMD lanes with a deterministic left-to-right lane reduction.
//!   The f64-accumulating kernels keep 4 lanes and therefore the scalar
//!   path's exact grouping (bit-identical across both builds); the
//!   f32-accumulating kernels widen to 8 lanes, which regroups the f32 sums
//!   — last-bit kernel-value differences on the simd leg only. Every
//!   in-tree bit-exactness assertion compares two paths within one build,
//!   and cross-path pins carry ≥1e-6 slack, so both CI legs run the full
//!   suite.
//!
//! # Accumulation contract
//!
//! The f32-accumulating kernels ([`dot_f32`], [`sq_dist_f32`]) carry
//! relative error O(n·eps_f32/L) in the row length n (L = lane count):
//! worst-case ~1e-3 relative at n = 100 000 on same-sign data, √n
//! random-walk in practice. `rust/tests/properties.rs` pins both against an
//! f64 reference on 100k-dim vectors. Anything that feeds a *decision sum*
//! accumulates in f64 instead ([`dot_f64_f32`], [`dot_f32_acc_f64`],
//! [`axpy_f64_f32`]) — quantized plans store f32 and accumulate f64 for
//! exactly this reason.

/// Whether this build's vector path is the explicit `std::simd` one
/// (`--features simd`, nightly) rather than the scalar 4-lane fallback.
/// Recorded in the `simd-summary.json` bench artifact so speedup claims are
/// attributable to a build mode.
#[inline]
pub const fn simd_enabled() -> bool {
    cfg!(feature = "simd")
}

/// Dense f32 dot product, f32 accumulation (see the module-level
/// accumulation contract). Length mismatch is a caller bug
/// (`debug_assert`); the loop trusts `a.len()`.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    active::dot_f32(a, b)
}

/// Squared euclidean distance with the same lane structure (and
/// accumulation contract) as [`dot_f32`]; clamped at 0 against roundoff.
#[inline]
pub fn sq_dist_f32(a: &[f32], b: &[f32]) -> f32 {
    active::sq_dist_f32(a, b)
}

/// f64-accumulated dot of an f64 weight vector with an f32 feature row,
/// truncating to the shorter length (the DCD solvers' and linear plans'
/// historical semantics: dimension mismatches score the overlap).
/// Bit-identical across the scalar and simd builds (4 f64 lanes both ways).
#[inline]
pub fn dot_f64_f32(w: &[f64], x: &[f32]) -> f64 {
    active::dot_f64_f32(w, x)
}

/// f64-accumulated dot of two f32 rows, truncating to the shorter length —
/// the quantized-plan scoring kernel (f32 storage, f64 accumulate; the
/// f32→f64 product widening is exact). Bit-identical across builds.
#[inline]
pub fn dot_f32_acc_f64(a: &[f32], b: &[f32]) -> f64 {
    active::dot_f32_acc_f64(a, b)
}

/// `y[j] += a * x[j]` over the overlap of `y` and `x` — the linear-kernel
/// collapse / lifted-primal accumulation. Elementwise (no cross-lane sum),
/// so it is bit-identical across builds and to the historical zip loops.
#[inline]
pub fn axpy_f64_f32(y: &mut [f64], a: f64, x: &[f32]) {
    active::axpy_f64_f32(y, a, x)
}

/// GEMV micro-kernel: `out[r] = ⟨w[r·cols .. (r+1)·cols], x⟩` for every
/// row of the row-major matrix `w` — the RFF lift's `Wx` product. Callers
/// that score many rows tile *around* this (see
/// [`crate::featmap::RffMap::lift_block`]) so a tile of `w` stays hot in
/// cache while every request row visits it.
#[inline]
pub fn block_dot_f32(w: &[f32], cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert!(cols > 0 && w.len() == cols * out.len(), "w must be out.len() x cols");
    for (wr, o) in w.chunks_exact(cols).zip(out.iter_mut()) {
        *o = active::dot_f32(wr, x);
    }
}

#[cfg(not(feature = "simd"))]
use self::scalar as active;
#[cfg(feature = "simd")]
use self::vector as active;

/// The stable-toolchain reference implementations: hand-unrolled 4-lane
/// loops, kept public so the bench's scalar-vs-SIMD section and the
/// property tests can compare against them on either build. On the default
/// build these *are* the public functions.
pub mod scalar {
    /// 4-lane f32 dot — the historical `kernel::dot` loop, verbatim.
    #[inline]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
        }
        let mut s = s0 + s1 + s2 + s3;
        for i in chunks * 4..n {
            s += a[i] * b[i];
        }
        s
    }

    /// 4-lane squared distance — the historical `kernel::sq_dist` loop.
    #[inline]
    pub fn sq_dist_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            let d0 = a[i] - b[i];
            let d1 = a[i + 1] - b[i + 1];
            let d2 = a[i + 2] - b[i + 2];
            let d3 = a[i + 3] - b[i + 3];
            s0 += d0 * d0;
            s1 += d1 * d1;
            s2 += d2 * d2;
            s3 += d3 * d3;
        }
        let mut s = s0 + s1 + s2 + s3;
        for i in chunks * 4..n {
            let d = a[i] - b[i];
            s += d * d;
        }
        s.max(0.0)
    }

    /// 4-lane f64×f32 dot — the historical `qp::dot_f64` loop, verbatim
    /// (including the truncating `min` length).
    #[inline]
    pub fn dot_f64_f32(w: &[f64], x: &[f32]) -> f64 {
        let n = w.len().min(x.len());
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += w[i] * x[i] as f64;
            s1 += w[i + 1] * x[i + 1] as f64;
            s2 += w[i + 2] * x[i + 2] as f64;
            s3 += w[i + 3] * x[i + 3] as f64;
        }
        let mut s = s0 + s1 + s2 + s3;
        for i in chunks * 4..n {
            s += w[i] * x[i] as f64;
        }
        s
    }

    /// 4-lane f32×f32 dot with f64 accumulation (products widened exactly).
    #[inline]
    pub fn dot_f32_acc_f64(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len().min(b.len());
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += a[i] as f64 * b[i] as f64;
            s1 += a[i + 1] as f64 * b[i + 1] as f64;
            s2 += a[i + 2] as f64 * b[i + 2] as f64;
            s3 += a[i + 3] as f64 * b[i + 3] as f64;
        }
        let mut s = s0 + s1 + s2 + s3;
        for i in chunks * 4..n {
            s += a[i] as f64 * b[i] as f64;
        }
        s
    }

    /// Elementwise `y += a·x` over the overlap — the historical zip loops.
    #[inline]
    pub fn axpy_f64_f32(y: &mut [f64], a: f64, x: &[f32]) {
        for (yj, xj) in y.iter_mut().zip(x) {
            *yj += a * *xj as f64;
        }
    }
}

/// Explicit portable-SIMD implementations (nightly `std::simd`). Lane sums
/// reduce left-to-right through `to_array()` so results are deterministic;
/// the f64 kernels keep 4 lanes to preserve the scalar path's exact
/// grouping, the f32 kernels widen to 8.
#[cfg(feature = "simd")]
mod vector {
    use std::simd::prelude::*;

    #[inline]
    fn hsum_f32(v: f32x8) -> f32 {
        v.to_array().iter().sum()
    }

    #[inline]
    fn hsum_f64(v: f64x4) -> f64 {
        v.to_array().iter().sum()
    }

    #[inline]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc = f32x8::splat(0.0);
        for c in 0..chunks {
            let i = c * 8;
            acc += f32x8::from_slice(&a[i..i + 8]) * f32x8::from_slice(&b[i..i + 8]);
        }
        let mut s = hsum_f32(acc);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    #[inline]
    pub fn sq_dist_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc = f32x8::splat(0.0);
        for c in 0..chunks {
            let i = c * 8;
            let d = f32x8::from_slice(&a[i..i + 8]) - f32x8::from_slice(&b[i..i + 8]);
            acc += d * d;
        }
        let mut s = hsum_f32(acc);
        for i in chunks * 8..n {
            let d = a[i] - b[i];
            s += d * d;
        }
        s.max(0.0)
    }

    #[inline]
    pub fn dot_f64_f32(w: &[f64], x: &[f32]) -> f64 {
        let n = w.len().min(x.len());
        let chunks = n / 4;
        let mut acc = f64x4::splat(0.0);
        for c in 0..chunks {
            let i = c * 4;
            let xv = f32x4::from_slice(&x[i..i + 4]).cast::<f64>();
            acc += f64x4::from_slice(&w[i..i + 4]) * xv;
        }
        let mut s = hsum_f64(acc);
        for i in chunks * 4..n {
            s += w[i] * x[i] as f64;
        }
        s
    }

    #[inline]
    pub fn dot_f32_acc_f64(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len().min(b.len());
        let chunks = n / 4;
        let mut acc = f64x4::splat(0.0);
        for c in 0..chunks {
            let i = c * 4;
            let av = f32x4::from_slice(&a[i..i + 4]).cast::<f64>();
            let bv = f32x4::from_slice(&b[i..i + 4]).cast::<f64>();
            acc += av * bv;
        }
        let mut s = hsum_f64(acc);
        for i in chunks * 4..n {
            s += a[i] as f64 * b[i] as f64;
        }
        s
    }

    #[inline]
    pub fn axpy_f64_f32(y: &mut [f64], a: f64, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 4;
        let av = f64x4::splat(a);
        for c in 0..chunks {
            let i = c * 4;
            let xv = f32x4::from_slice(&x[i..i + 4]).cast::<f64>();
            let yv = f64x4::from_slice(&y[i..i + 4]) + av * xv;
            yv.copy_to_slice(&mut y[i..i + 4]);
        }
        for i in chunks * 4..n {
            y[i] += a * x[i] as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_pair(rng: &mut Pcg32, n: usize) -> (Vec<f32>, Vec<f32>) {
        let a = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let b = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        (a, b)
    }

    /// Lengths that exercise empty, sub-lane, lane-boundary, and tail cases
    /// for both the 4-lane scalar and 8-lane vector paths.
    const LENGTHS: [usize; 10] = [0, 1, 3, 4, 7, 8, 9, 31, 64, 257];

    #[test]
    fn scalar_path_is_the_historical_loop_bit_for_bit() {
        // The spec the dedupe satellite pins: the scalar micro-kernels must
        // reproduce the deleted per-module copies exactly. The reference
        // loops here are sequential f64/f32 re-derivations only for axpy
        // (elementwise, order-free); for the 4-lane sums the scalar module
        // *is* the historical code, so pin the public functions against it
        // on the default build.
        let mut rng = Pcg32::seeded(0x51AD);
        for n in LENGTHS {
            let (a, b) = random_pair(&mut rng, n);
            let w: Vec<f64> = a.iter().map(|v| *v as f64 * 1.5).collect();
            #[cfg(not(feature = "simd"))]
            {
                assert_eq!(dot_f32(&a, &b).to_bits(), scalar::dot_f32(&a, &b).to_bits());
                assert_eq!(sq_dist_f32(&a, &b).to_bits(), scalar::sq_dist_f32(&a, &b).to_bits());
            }
            // f64-accumulating kernels keep 4 lanes on both builds: the
            // public path must match the scalar reference bit-for-bit even
            // with --features simd.
            assert_eq!(dot_f64_f32(&w, &b).to_bits(), scalar::dot_f64_f32(&w, &b).to_bits());
            assert_eq!(
                dot_f32_acc_f64(&a, &b).to_bits(),
                scalar::dot_f32_acc_f64(&a, &b).to_bits()
            );
            let mut y1: Vec<f64> = w.clone();
            let mut y2: Vec<f64> = w.clone();
            axpy_f64_f32(&mut y1, 0.75, &b);
            scalar::axpy_f64_f32(&mut y2, 0.75, &b);
            for (p, q) in y1.iter().zip(&y2) {
                assert_eq!(p.to_bits(), q.to_bits(), "axpy must be elementwise-identical");
            }
        }
    }

    #[test]
    fn vector_and_scalar_agree_within_f32_regrouping() {
        // On the simd build the 8-lane f32 kernels regroup the sum; on the
        // default build both sides are the same code. Either way the
        // agreement bound is f32 regrouping noise, far inside 1e-5 relative
        // at these lengths.
        let mut rng = Pcg32::seeded(0xC0DE);
        for n in LENGTHS {
            let (a, b) = random_pair(&mut rng, n);
            let (d1, d2) = (dot_f32(&a, &b) as f64, scalar::dot_f32(&a, &b) as f64);
            assert!((d1 - d2).abs() <= 1e-5 * (1.0 + d2.abs()), "n={n}: {d1} vs {d2}");
            let (q1, q2) = (sq_dist_f32(&a, &b) as f64, scalar::sq_dist_f32(&a, &b) as f64);
            assert!((q1 - q2).abs() <= 1e-5 * (1.0 + q2.abs()), "n={n}: {q1} vs {q2}");
        }
    }

    #[test]
    fn truncating_kernels_score_the_overlap() {
        // dot_f64_f32 / dot_f32_acc_f64 / axpy keep the historical
        // truncating semantics: mismatched lengths use the shorter side.
        let w = vec![1.0f64, 2.0, 3.0, 4.0, 5.0];
        let x = vec![1.0f32, 1.0, 1.0];
        assert_eq!(dot_f64_f32(&w, &x), 6.0);
        assert_eq!(dot_f64_f32(&w[..2], &x), 3.0);
        let a = vec![2.0f32, 2.0];
        assert_eq!(dot_f32_acc_f64(&a, &x), 4.0);
        let mut y = vec![0.0f64; 5];
        axpy_f64_f32(&mut y, 2.0, &x);
        assert_eq!(y, vec![2.0, 2.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn block_dot_matches_per_row_dots() {
        let mut rng = Pcg32::seeded(7);
        let (rows, cols) = (13, 37);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32() - 0.5).collect();
        let x: Vec<f32> = (0..cols).map(|_| rng.next_f32() - 0.5).collect();
        let mut out = vec![0.0f32; rows];
        block_dot_f32(&w, cols, &x, &mut out);
        for (r, o) in out.iter().enumerate() {
            let want = dot_f32(&w[r * cols..(r + 1) * cols], &x);
            assert_eq!(o.to_bits(), want.to_bits(), "row {r}");
        }
    }

    #[test]
    fn widened_products_are_exact() {
        // f32→f64 widening before the product makes each term exact, so on
        // power-of-two values the f64-accumulated kernels are exact sums.
        let a = vec![0.5f32, 0.25, 2.0, 8.0, 0.125];
        let b = vec![4.0f32, 8.0, 0.5, 0.25, 16.0];
        assert_eq!(dot_f32_acc_f64(&a, &b), 2.0 + 2.0 + 1.0 + 2.0 + 2.0);
    }
}
