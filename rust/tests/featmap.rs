//! Feature-map approximation integration tests (ISSUE 7 acceptance
//! fixtures): RFF-trained models must track exact RBF accuracy at large
//! map dimension, accuracy must be monotone (within noise) in the map
//! dimension, the Nyström map with a full landmark budget must reproduce
//! exact-RBF decisions, feature-mapped artifacts must round-trip through
//! JSON bit-exactly, and an RFF artifact must serve over the TCP frontend
//! identically to the in-process runtime.

use std::net::TcpListener;
use std::sync::Arc;

use sodm::api::{self, Artifact, Method, TrainSpec};
use sodm::data::synth::SynthSpec;
use sodm::data::Dataset;
use sodm::kernel::KernelKind;
use sodm::net::{ModelRegistry, NetClient, NetServer};
use sodm::odm::OdmModel;
use sodm::qp::SolveBudget;
use sodm::serve::ServeConfig;

fn loopback_available() -> bool {
    TcpListener::bind("127.0.0.1:0").is_ok()
}

fn fixture(rows: usize, seed: u64) -> (Dataset, Dataset) {
    let mut sgen = SynthSpec::named("svmguide1", 0.02, seed);
    sgen.rows = rows;
    sgen.generate().split(0.8, seed ^ 0xF1)
}

/// Shrinking off and a generous sweep budget: both the exact-kernel and
/// lifted-linear solvers run plain DCD to (near) convergence, so their
/// optima — not their iteration paths — are what the tests compare.
fn rbf_spec(gamma: f32) -> TrainSpec {
    let budget = SolveBudget { max_sweeps: 200, shrink: false, ..SolveBudget::default() };
    TrainSpec::new(Method::ExactOdm).kernel(KernelKind::Rbf { gamma }).budget(budget).seed(9)
}

fn sign_agreement(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let same = a.iter().zip(b).filter(|(x, y)| (**x >= 0.0) == (**y >= 0.0)).count();
    same as f64 / a.len() as f64
}

#[test]
fn rff_tracks_exact_rbf_at_large_dimension() {
    let (train, test) = fixture(600, 7);
    let exact = api::train(&rbf_spec(0.5).build().unwrap(), &train).unwrap();
    let rff = api::train(&rbf_spec(0.5).rff(1536).build().unwrap(), &train).unwrap();
    let exact_acc = exact.accuracy(&test).unwrap();
    let rff_acc = rff.accuracy(&test).unwrap();
    assert!(
        rff_acc + 0.02 >= exact_acc,
        "rff at D=1536 must track exact rbf: {rff_acc:.4} vs {exact_acc:.4}"
    );
    let agree = sign_agreement(
        &exact.as_binary().unwrap().decisions(&test),
        &rff.as_binary().unwrap().decisions(&test),
    );
    assert!(agree >= 0.95, "decision agreement at D=1536 was only {agree:.3}");
}

#[test]
fn rff_accuracy_is_monotone_in_dimension_within_noise() {
    let (train, test) = fixture(600, 11);
    let acc = |dim: usize| {
        let art = api::train(&rbf_spec(0.5).rff(dim).build().unwrap(), &train).unwrap();
        art.accuracy(&test).unwrap()
    };
    let (lo, mid, hi) = (acc(8), acc(64), acc(512));
    assert!(mid + 0.03 >= lo, "D=64 ({mid:.4}) fell behind D=8 ({lo:.4})");
    assert!(hi + 0.03 >= mid, "D=512 ({hi:.4}) fell behind D=64 ({mid:.4})");
    assert!(hi + 0.03 >= lo, "D=512 ({hi:.4}) fell behind D=8 ({lo:.4})");
}

#[test]
fn nystrom_with_full_landmark_budget_matches_exact_rbf() {
    // With the landmark budget covering every training row, the Nyström
    // kernel estimate is exact at the landmarks, so decisions coincide
    // with the exact-RBF model up to solver/float tolerance.
    let (train, test) = fixture(300, 13);
    let exact = api::train(&rbf_spec(0.5).build().unwrap(), &train).unwrap();
    let ny = api::train(&rbf_spec(0.5).nystrom(train.rows).build().unwrap(), &train).unwrap();
    let exact_acc = exact.accuracy(&test).unwrap();
    let ny_acc = ny.accuracy(&test).unwrap();
    assert!(
        (exact_acc - ny_acc).abs() <= 0.03,
        "full-landmark nystrom must match exact rbf: {ny_acc:.4} vs {exact_acc:.4}"
    );
    let agree = sign_agreement(
        &exact.as_binary().unwrap().decisions(&test),
        &ny.as_binary().unwrap().decisions(&test),
    );
    assert!(agree >= 0.95, "full-landmark nystrom decision agreement was only {agree:.3}");
}

#[test]
fn feature_mapped_artifact_round_trips_bit_exact() {
    let (train, test) = fixture(200, 17);
    for spec in [rbf_spec(0.5).rff(128), rbf_spec(0.5).nystrom(24)] {
        let art = api::train(&spec.build().unwrap(), &train).unwrap();
        let before = art.as_binary().unwrap().decisions(&test);

        let dir = std::env::temp_dir().join(format!("sodm_featmap_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("featmap_model.json");
        art.save(&path).unwrap();
        let loaded = Artifact::load(path.to_str().unwrap()).unwrap();
        let _ = std::fs::remove_file(&path);

        assert!(matches!(loaded.as_binary(), Some(OdmModel::FeatureMapped { .. })));
        assert_eq!(loaded.meta.feature_map, art.meta.feature_map);
        assert_eq!(loaded.meta.feature_dim, art.meta.feature_dim);
        assert_eq!(loaded.meta.feature_seed, art.meta.feature_seed);
        let after = loaded.as_binary().unwrap().decisions(&test);
        for (i, (a, b)) in before.iter().zip(&after).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "row {i}: {a} vs {b} after round-trip");
        }
    }
}

#[test]
fn rff_artifact_serves_over_the_tcp_frontend() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let (train, test) = fixture(200, 19);
    let artifact = api::train(&rbf_spec(0.5).rff(128).build().unwrap(), &train).unwrap();
    assert!(matches!(artifact.as_binary(), Some(OdmModel::FeatureMapped { .. })));
    let reference = artifact.serve(ServeConfig::default()).unwrap();

    let cfg = ServeConfig { workers: 2, shards: 2, ..ServeConfig::default() };
    let registry = Arc::new(ModelRegistry::start(artifact, cfg).unwrap());
    let server = NetServer::bind("127.0.0.1:0", registry).unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    for i in 0..24 {
        let x = test.row(i * 3 % test.rows);
        let want = reference.score(x).unwrap();
        let got = client.score(x).unwrap().value().unwrap();
        assert!((got - want).abs() < 1e-9, "row {i}: remote {got} vs in-process {want}");
    }
    reference.stop();
    server.stop();
}
