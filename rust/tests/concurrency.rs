//! Concurrency/serialization test blitz (ISSUE 4 satellites):
//! `util::pool::WorkQueue` close/drain/multi-producer semantics under
//! schedule-shaking loops (loom-style repetition with plain threads,
//! deterministic job sets), and `serve::LatencyHistogram` percentile
//! correctness against exact sorted references on adversarial
//! distributions.

use std::sync::atomic::{AtomicUsize, Ordering};

use sodm::serve::LatencyHistogram;
use sodm::util::pool::WorkQueue;

#[test]
fn close_while_workers_blocked_wakes_all_poppers() {
    // Repeat the race with varying pre-close delays so the close lands
    // both before and after the poppers park on the condvar.
    for round in 0..50u64 {
        let q: WorkQueue<usize> = WorkQueue::new();
        let registered = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let (q, registered) = (&q, &registered);
                    s.spawn(move || {
                        registered.fetch_add(1, Ordering::SeqCst);
                        q.pop()
                    })
                })
                .collect();
            while registered.load(Ordering::SeqCst) < 4 {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_micros(50 * (round % 5)));
            q.close();
            for h in handles {
                assert_eq!(h.join().unwrap(), None, "round {round}: popper must wake with None");
            }
        });
        assert_eq!(q.pop(), None, "closed queue stays closed");
    }
}

#[test]
fn close_then_drain_delivers_every_queued_job_exactly_once() {
    for round in 0..20usize {
        let q: WorkQueue<usize> = WorkQueue::new();
        let jobs = 500 + round * 13;
        for j in 0..jobs {
            assert!(q.push(j));
        }
        q.close();
        assert!(!q.push(usize::MAX), "push after close must be refused");
        let mut got = std::thread::scope(|s| {
            let consumers: Vec<_> = (0..5)
                .map(|_| {
                    s.spawn(|| {
                        let mut mine = Vec::new();
                        while let Some(j) = q.pop() {
                            mine.push(j);
                        }
                        mine
                    })
                })
                .collect();
            consumers.into_iter().flat_map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        got.sort_unstable();
        assert_eq!(got, (0..jobs).collect::<Vec<_>>(), "round {round}: jobs lost or duplicated");
        assert!(q.is_empty());
    }
}

#[test]
fn multi_producer_push_is_lossless_under_concurrent_drain() {
    for round in 0..10u64 {
        let q: WorkQueue<u64> = WorkQueue::new();
        let (producers, per) = (4u64, 300u64);
        let mut got = std::thread::scope(|s| {
            let pushers: Vec<_> = (0..producers)
                .map(|p| {
                    let q = &q;
                    s.spawn(move || {
                        for j in 0..per {
                            assert!(q.push(p * per + j), "queue closed under producers");
                        }
                    })
                })
                .collect();
            let consumers: Vec<_> = (0..3)
                .map(|_| {
                    s.spawn(|| {
                        let mut mine = Vec::new();
                        while let Some(j) = q.pop() {
                            mine.push(j);
                        }
                        mine
                    })
                })
                .collect();
            for h in pushers {
                h.join().unwrap();
            }
            q.close();
            consumers.into_iter().flat_map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        got.sort_unstable();
        assert_eq!(
            got,
            (0..producers * per).collect::<Vec<_>>(),
            "round {round}: concurrent production must be lossless"
        );
    }
}

#[test]
fn single_consumer_preserves_per_producer_fifo_order() {
    let q: WorkQueue<(u64, u64)> = WorkQueue::new();
    std::thread::scope(|s| {
        let pushers: Vec<_> = (0..3u64)
            .map(|p| {
                let q = &q;
                s.spawn(move || {
                    for j in 0..200u64 {
                        assert!(q.push((p, j)));
                    }
                })
            })
            .collect();
        let consumer = s.spawn(|| {
            let mut seen: Vec<Vec<u64>> = vec![Vec::new(); 3];
            while let Some((p, j)) = q.pop() {
                seen[p as usize].push(j);
            }
            seen
        });
        for h in pushers {
            h.join().unwrap();
        }
        q.close();
        let seen = consumer.join().unwrap();
        for (p, js) in seen.iter().enumerate() {
            assert_eq!(js.len(), 200, "producer {p}: all jobs delivered");
            assert!(js.windows(2).all(|w| w[0] < w[1]), "producer {p}: FIFO order broken");
        }
    });
}

// --- LatencyHistogram percentile correctness -------------------------------

/// Exact nearest-rank percentile of an (unsorted) sample set, microseconds.
fn exact_percentile_us(samples: &mut Vec<u64>, p: f64) -> u64 {
    samples.sort_unstable();
    let rank = ((p / 100.0) * samples.len() as f64).ceil().max(1.0) as usize;
    samples[rank - 1]
}

/// The log2-bucket contract: the reported percentile is the closing
/// bucket's upper bound, so it is always above the exact sample percentile
/// and at most 2x it (for samples >= 1 us).
fn assert_bucket_contract(hist: &LatencyHistogram, samples: &mut Vec<u64>, p: f64) {
    let exact_us = exact_percentile_us(samples, p).max(1);
    let exact_ms = exact_us as f64 / 1e3;
    let got_ms = hist.percentile_ms(p);
    assert!(got_ms > exact_ms * 0.999_999, "p{p}: reported {got_ms} ms below exact {exact_ms} ms");
    assert!(
        got_ms <= exact_ms * 2.0 + 1e-9,
        "p{p}: reported {got_ms} ms beyond 2x exact {exact_ms} ms"
    );
}

#[test]
fn histogram_all_equal_distribution() {
    let hist = LatencyHistogram::new();
    let mut samples = Vec::new();
    for _ in 0..1000 {
        hist.record_us(700);
        samples.push(700u64);
    }
    assert_eq!(hist.count(), 1000);
    for p in [50.0, 95.0, 99.0, 100.0] {
        assert_bucket_contract(&hist, &mut samples, p);
    }
    // one bucket means every percentile reports the same bound
    assert_eq!(hist.percentile_ms(50.0), hist.percentile_ms(99.0));
    assert_eq!(hist.percentile_ms(50.0), 1.024, "700 us lands in [512, 1024) -> 1024 us");
}

#[test]
fn histogram_bimodal_distribution() {
    let hist = LatencyHistogram::new();
    let mut samples = Vec::new();
    for i in 0..1000u64 {
        let us = if i % 10 == 9 { 1 << 20 } else { 100 };
        hist.record_us(us);
        samples.push(us);
    }
    for p in [50.0, 90.0, 95.0, 99.0] {
        assert_bucket_contract(&hist, &mut samples, p);
    }
    // p50 sits in the fast mode, p95/p99 in the slow mode
    assert!(hist.percentile_ms(50.0) < 1.0);
    assert!(hist.percentile_ms(95.0) > 1000.0);
}

#[test]
fn histogram_single_sample() {
    let hist = LatencyHistogram::new();
    hist.record_us(5);
    assert_eq!(hist.count(), 1);
    let mut samples = vec![5u64];
    for p in [50.0, 99.0, 100.0] {
        assert_bucket_contract(&hist, &mut samples, p);
    }
    assert_eq!(hist.percentile_ms(50.0), 0.008, "5 us lands in [4, 8) -> 8 us");
}

#[test]
fn histogram_zero_and_empty_edges() {
    let hist = LatencyHistogram::new();
    assert_eq!(hist.percentile_ms(99.0), 0.0, "no samples reports 0");
    hist.record_us(0); // clamped to the first bucket
    assert_eq!(hist.percentile_ms(50.0), 0.002, "[1, 2) -> 2 us");
}

#[test]
fn histogram_saturates_at_top_bucket() {
    let hist = LatencyHistogram::new();
    hist.record_us(u64::MAX);
    hist.record_us(1 << 40);
    assert_eq!(hist.count(), 2);
    // both clamp into the top bucket (>= ~9 minutes)
    let top_ms = (1u64 << 30) as f64 / 1e3;
    assert_eq!(hist.percentile_ms(50.0), top_ms);
    assert_eq!(hist.percentile_ms(100.0), top_ms);
}
