//! Property-based tests (in-crate harness: deterministic Pcg32 case
//! generation, many random cases per property — the offline stand-in for
//! proptest; failures print the offending case seed).

use sodm::data::{all_indices, synth::SynthSpec, DataView, Dataset};
use sodm::kernel::{signed_row, KernelKind};
use sodm::odm::{OdmModel, OdmParams};
use sodm::partition::{make_partitions, partitions_valid, PartitionStrategy};
use sodm::qp::{solve_odm_dual, solve_svm_dual, SolveBudget};
use sodm::util::json::Json;
use sodm::util::rng::Pcg32;

fn random_dataset(rng: &mut Pcg32, rows: usize, cols: usize) -> Dataset {
    let mut x = Vec::with_capacity(rows * cols);
    let mut y = Vec::with_capacity(rows);
    for _ in 0..rows {
        for _ in 0..cols {
            x.push(rng.next_f32());
        }
        y.push(if rng.gen_bool(0.5) { 1.0 } else { -1.0 });
    }
    Dataset::new("prop", x, y, cols)
}

#[test]
fn prop_partitions_always_valid() {
    // Any strategy, any (k, rows, cols) in range: disjoint cover, non-empty.
    let mut rng = Pcg32::seeded(0xA11);
    for case in 0..25 {
        let rows = 24 + rng.gen_range(200);
        let cols = 2 + rng.gen_range(10);
        let k = 2 + rng.gen_range(5.min(rows / 4));
        let ds = random_dataset(&mut rng, rows, cols);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let strategy = match rng.gen_range(4) {
            0 => PartitionStrategy::Random,
            1 => PartitionStrategy::StratifiedRkhs { stratums: 2 + rng.gen_range(8) },
            2 => PartitionStrategy::KmeansProportional { clusters: 2 + rng.gen_range(6) },
            _ => PartitionStrategy::KernelKmeansClusters { embed_dim: 2 + rng.gen_range(8) },
        };
        let kernel = if rng.gen_bool(0.5) {
            KernelKind::Linear
        } else {
            KernelKind::Rbf { gamma: 0.1 + rng.next_f32() * 3.0 }
        };
        let parts = make_partitions(&view, &kernel, k, strategy, case as u64, 1);
        assert!(
            partitions_valid(&view, &parts),
            "case {case}: invalid partition rows={rows} k={k} {strategy:?}"
        );
        assert_eq!(parts.len(), k, "case {case}");
    }
}

#[test]
fn prop_odm_dcd_kkt_and_feasibility() {
    // Random data + random hyperparameters: the solver must return a
    // feasible point whose projected-gradient violation meets eps whenever
    // it reports convergence, and whose objective is below the zero point.
    let mut rng = Pcg32::seeded(0xB22);
    for case in 0..15 {
        let rows = 20 + rng.gen_range(80);
        let cols = 2 + rng.gen_range(6);
        let ds = random_dataset(&mut rng, rows, cols);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let params = OdmParams {
            lambda: 0.5 + rng.next_f32() * 32.0,
            theta: rng.next_f32() * 0.8,
            upsilon: 0.1 + rng.next_f32() * 0.9,
        };
        let kernel = if rng.gen_bool(0.5) {
            KernelKind::Linear
        } else {
            KernelKind::Rbf { gamma: 0.1 + rng.next_f32() * 2.0 }
        };
        let budget = SolveBudget { eps: 1e-4, max_sweeps: 2000, ..Default::default() };
        let sol = solve_odm_dual(&view, &kernel, &params, None, &budget);
        assert!(sol.zeta.iter().all(|v| *v >= 0.0), "case {case}: ζ infeasible");
        assert!(sol.beta.iter().all(|v| *v >= 0.0), "case {case}: β infeasible");
        if sol.stats.converged {
            assert!(
                sol.stats.max_violation < 1e-4,
                "case {case}: converged but violation {}",
                sol.stats.max_violation
            );
        }
        // d(0,0) = 0; any descent step from 0 gives a strictly lower value.
        assert!(sol.stats.objective <= 1e-9, "case {case}: objective {}", sol.stats.objective);
    }
}

#[test]
fn prop_warm_start_never_hurts_objective() {
    let mut rng = Pcg32::seeded(0xC33);
    for case in 0..10 {
        let rows = 30 + rng.gen_range(60);
        let ds = random_dataset(&mut rng, rows, 4);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let params = OdmParams::default();
        let kernel = KernelKind::Rbf { gamma: 1.0 };
        let short = SolveBudget { max_sweeps: 3, ..Default::default() };
        let partial = solve_odm_dual(&view, &kernel, &params, None, &short);
        let warm = solve_odm_dual(&view, &kernel, &params, Some(&partial.alpha()), &short);
        assert!(
            warm.stats.objective <= partial.stats.objective + 1e-9,
            "case {case}: warm {} > cold {}",
            warm.stats.objective,
            partial.stats.objective
        );
    }
}

#[test]
fn prop_svm_box_constraints_hold() {
    let mut rng = Pcg32::seeded(0xD44);
    for case in 0..10 {
        let rows = 20 + rng.gen_range(60);
        let ds = random_dataset(&mut rng, rows, 3);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let c = (0.1 + rng.next_f64() * 10.0).round() / 10.0 + 0.1;
        let kernel = KernelKind::Rbf { gamma: 0.5 + rng.next_f32() };
        let sol = solve_svm_dual(&view, &kernel, c, None, &SolveBudget::default());
        assert!(
            sol.gamma.iter().all(|g| (-1e-12..=c + 1e-12).contains(g)),
            "case {case}: box violated (C={c})"
        );
    }
}

#[test]
fn prop_gram_row_symmetry_and_sign() {
    // Q_ij == Q_ji and sign(Q_ij) == y_i y_j sign(k) for random data.
    let mut rng = Pcg32::seeded(0xE55);
    for case in 0..10 {
        let rows = 10 + rng.gen_range(30);
        let cols = 1 + rng.gen_range(8);
        let ds = random_dataset(&mut rng, rows, cols);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let kernel = KernelKind::Rbf { gamma: 0.3 + rng.next_f32() };
        let i = rng.gen_range(rows);
        let j = rng.gen_range(rows);
        let mut ri = vec![0.0f32; rows];
        let mut rj = vec![0.0f32; rows];
        signed_row(&view, &kernel, i, &mut ri);
        signed_row(&view, &kernel, j, &mut rj);
        assert!((ri[j] - rj[i]).abs() < 1e-6, "case {case}: asymmetry");
        let expected_sign = ds.y[i] * ds.y[j];
        assert!(
            ri[j] * expected_sign >= 0.0,
            "case {case}: sign violated (rbf kernel values are positive)"
        );
    }
}

#[test]
fn prop_model_json_round_trip() {
    let mut rng = Pcg32::seeded(0xF66);
    for case in 0..10 {
        let n = 1 + rng.gen_range(20);
        let model = if rng.gen_bool(0.5) {
            OdmModel::Linear {
                w: (0..n).map(|_| (rng.next_f64() - 0.5) * 10.0).collect(),
            }
        } else {
            let svs = 1 + rng.gen_range(10);
            OdmModel::Kernel {
                kernel: KernelKind::Rbf { gamma: rng.next_f32() + 0.01 },
                sv_x: (0..svs * n).map(|_| rng.next_f32()).collect(),
                coef: (0..svs).map(|_| (rng.next_f64() - 0.5) * 4.0).collect(),
                cols: n,
            }
        };
        let j = model.to_json().to_string();
        let back = OdmModel::from_json(&Json::parse(&j).unwrap()).unwrap();
        let x: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let (a, b) = (model.decision(&x), back.decision(&x));
        assert!(
            (a - b).abs() < 1e-9 * (1.0 + a.abs()),
            "case {case}: decision drift {a} vs {b}"
        );
    }
}

#[test]
fn prop_split_preserves_all_rows() {
    let mut rng = Pcg32::seeded(0x077);
    for case in 0..10 {
        let rows = 10 + rng.gen_range(200);
        let ds = random_dataset(&mut rng, rows, 3);
        let frac = 0.3 + rng.next_f64() * 0.6;
        let (tr, te) = ds.split(frac, case as u64);
        assert_eq!(tr.rows + te.rows, rows, "case {case}");
        assert!(tr.rows >= 1 && te.rows >= 1, "case {case}");
    }
}

#[test]
fn prop_f32_kernel_accumulation_tracks_f64_reference() {
    // The accumulation contract of the vectorized core (rust/src/simd):
    // `kernel::dot` / `kernel::sq_dist` accumulate in f32 across 4 (scalar)
    // or 8 (simd) independent lanes, so the worst-case relative error is
    // O(n·eps_f32 / lanes) — about 1e-3 at n = 100 000 — while random data
    // sits in the much smaller sqrt(n) random-walk regime. Pin both kernels
    // against an exact f64 reference at the documented bound.
    let mut rng = Pcg32::seeded(0x51D);
    let n = 100_000usize;
    for case in 0..4 {
        let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let dot64: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        let dot32 = sodm::kernel::dot(&a, &b) as f64;
        // The roundoff accrues on the magnitude sum, not the (cancelling)
        // signed sum — that's the scale the bound is relative to.
        let mag: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum();
        assert!(
            (dot32 - dot64).abs() <= 1e-3 * mag.max(1.0),
            "case {case}: dot drift {} exceeds 1e-3 x {mag}",
            (dot32 - dot64).abs()
        );
        let sq64: f64 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| {
                let d = (*x - *y) as f64;
                d * d
            })
            .sum();
        let sq32 = sodm::kernel::sq_dist(&a, &b) as f64;
        assert!(
            (sq32 - sq64).abs() <= 1e-3 * sq64.max(1.0),
            "case {case}: sq_dist drift {sq32} vs {sq64}"
        );
    }
}

#[test]
fn prop_synth_profiles_generate_consistently() {
    let mut rng = Pcg32::seeded(0x188);
    for _ in 0..8 {
        let names = ["svmguide1", "phishing", "cod-rna", "SUSY"];
        let name = names[rng.gen_range(names.len())];
        let scale = 0.005 + rng.next_f64() * 0.02;
        let seed = rng.next_u64();
        let a = SynthSpec::named(name, scale, seed).generate();
        let b = SynthSpec::named(name, scale, seed).generate();
        assert_eq!(a.x, b.x);
        assert!(a.x.iter().all(|v| (0.0..=1.0).contains(v)));
        assert!(a.rows >= 64);
    }
}
