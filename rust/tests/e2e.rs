//! End-to-end integration: full pipelines on emulated data, method ordering
//! sanity, CLI binary smoke tests.

use std::process::Command;

use sodm::data::synth::SynthSpec;
use sodm::exp::{prepare_dataset, rbf_for, run_qp_method, run_sodm_linear, ExpConfig};
use sodm::kernel::KernelKind;
use sodm::odm::OdmParams;
use sodm::sodm::{train_sodm, SodmConfig};

fn cfg() -> ExpConfig {
    ExpConfig {
        scale: 0.02,
        workers: 2,
        datasets: vec!["svmguide1".into()],
        out_dir: sodm::util::temp_dir("e2e"),
        ..Default::default()
    }
}

#[test]
fn all_methods_beat_majority_class_rbf() {
    let cfg = cfg();
    let (train, test) = prepare_dataset("svmguide1", &cfg);
    let majority = test.positive_fraction().max(1.0 - test.positive_fraction());
    let k = rbf_for(&train);
    for m in ["ODM", "Ca-ODM", "DiP-ODM", "DC-ODM", "SODM", "SSVM", "Ca-SVM", "DiP-SVM", "DC-SVM"]
    {
        let r = run_qp_method(m, &train, &test, &k, &cfg);
        assert!(
            r.accuracy > majority,
            "{m}: accuracy {} vs majority {majority}",
            r.accuracy
        );
    }
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn sodm_competitive_with_exact_on_two_datasets() {
    let cfg = cfg();
    for name in ["svmguide1", "cod-rna"] {
        let (train, test) = prepare_dataset(name, &cfg);
        let k = rbf_for(&train);
        let exact = run_qp_method("ODM", &train, &test, &k, &cfg);
        let sodm_r = run_qp_method("SODM", &train, &test, &k, &cfg);
        assert!(
            sodm_r.accuracy >= exact.accuracy - 0.05,
            "{name}: SODM {} vs ODM {}",
            sodm_r.accuracy,
            exact.accuracy
        );
    }
}

#[test]
fn sodm_linear_dsvrg_learns() {
    let cfg = cfg();
    let (train, test) = prepare_dataset("svmguide1", &cfg);
    let r = run_sodm_linear(&train, &test, &cfg);
    assert!(r.accuracy > 0.85, "DSVRG accuracy {}", r.accuracy);
    assert!(r.curve.len() >= 3, "expected per-1/3-epoch checkpoints");
}

#[test]
fn nonlinear_dataset_rbf_beats_linear() {
    // cod-rna's emulated profile is XOR-like: RBF SODM must beat linear by a
    // clear margin — the reason Table 2 and Table 3 differ.
    let cfg = ExpConfig { scale: 0.05, ..cfg() };
    let (train, test) = prepare_dataset("cod-rna", &cfg);
    let rbf = run_qp_method("SODM", &train, &test, &rbf_for(&train), &cfg);
    let lin = run_sodm_linear(&train, &test, &cfg);
    assert!(
        rbf.accuracy > lin.accuracy + 0.03,
        "rbf {} vs linear {}",
        rbf.accuracy,
        lin.accuracy
    );
}

#[test]
fn sodm_deterministic_given_seed() {
    let spec = SynthSpec::named("svmguide1", 0.02, 5);
    let ds = spec.generate();
    let k = KernelKind::Rbf { gamma: 1.0 };
    let p = OdmParams::default();
    let scfg = SodmConfig::with_tree(2, 2, 8);
    let a = train_sodm(&ds, &k, &p, &scfg, None);
    let b = train_sodm(&ds, &k, &p, &scfg, None);
    // same partitioning + same sweep order -> identical models
    assert_eq!(a.support_size(), b.support_size());
    let x = ds.row(0);
    assert!((a.decision(x) - b.decision(x)).abs() < 1e-9);
}

// --- CLI smoke tests (run the actual binary) ---

fn sodm_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sodm"))
}

#[test]
fn cli_info_runs() {
    let out = sodm_bin().arg("info").output().expect("spawn sodm");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cpus:"), "{text}");
}

#[test]
fn cli_gen_train_predict_round_trip() {
    let dir = sodm::util::temp_dir("cli");
    let data = dir.join("toy.libsvm");
    let model = dir.join("model.json");
    let out = sodm_bin()
        .args(["gen-data", "--name", "svmguide1", "--scale", "0.02", "--out"])
        .arg(&data)
        .output()
        .expect("gen-data");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = sodm_bin()
        .args(["train", "--data"])
        .arg(&data)
        .args(["--method", "sodm", "--kernel", "rbf", "--gamma", "1.0", "--model-out"])
        .arg(&model)
        .output()
        .expect("train");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("test_acc="), "{text}");

    let out = sodm_bin()
        .args(["predict", "--model"])
        .arg(&model)
        .args(["--data"])
        .arg(&data)
        .output()
        .expect("predict");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("accuracy="), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_sparse_train_runs() {
    let out = sodm_bin()
        .args([
            "train",
            "--data",
            "sparse-synth:400:2000:0.02",
            "--kernel",
            "linear",
            "--method",
            "dsvrg",
        ])
        .output()
        .expect("train sparse");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("nnz="), "{text}");
    assert!(text.contains("test_acc="), "{text}");
}

#[test]
fn cli_unknown_command_fails() {
    let out = sodm_bin().arg("frobnicate").output().expect("spawn");
    assert!(!out.status.success());
}

#[test]
fn cli_experiment_table1() {
    let out = sodm_bin().args(["experiment", "--table", "1"]).output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("SUSY"));
}
