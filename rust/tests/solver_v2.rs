//! Working-set DCD v2 equivalence and telemetry tests (ISSUE 1).
//!
//! The shrinking solver must reach the no-shrink reference solver's dual
//! objective within the solve tolerance with the same support set while
//! performing measurably fewer coordinate updates, across seeds, on both the
//! kernel and linear paths; warm-started merge solves must stay
//! deterministic; and `SolveStats` telemetry must be internally consistent.

use sodm::data::{all_indices, DataView, Dataset};
use sodm::kernel::KernelKind;
use sodm::odm::OdmParams;
use sodm::qp::{solve_odm_dual, solve_svm_dual, SolveBudget};
use sodm::sodm::{train_sodm_traced, SodmConfig};
use sodm::util::rng::Pcg32;

fn random_dataset(rng: &mut Pcg32, rows: usize, cols: usize) -> Dataset {
    let mut x = Vec::with_capacity(rows * cols);
    let mut y = Vec::with_capacity(rows);
    for _ in 0..rows {
        for _ in 0..cols {
            x.push(rng.next_f32());
        }
        y.push(if rng.gen_bool(0.5) { 1.0 } else { -1.0 });
    }
    Dataset::new("v2", x, y, cols)
}

fn params() -> OdmParams {
    OdmParams { lambda: 8.0, theta: 0.3, upsilon: 0.5 }
}

fn tight() -> SolveBudget {
    SolveBudget { eps: 1e-5, max_sweeps: 3000, ..Default::default() }
}

/// Core equivalence property (ISSUE acceptance criterion): for every seed,
/// the shrunk solver and the `--no-shrink` reference reach the same
/// objective and support set, with the shrunk solve spending no more — and
/// in aggregate measurably fewer — coordinate updates.
fn check_odm_equivalence(kernel: &KernelKind, seeds: std::ops::Range<u64>) {
    let p = params();
    let shrunk_budget = tight();
    let reference_budget = SolveBudget { shrink: false, ..tight() };
    let mut total_shrunk = 0u64;
    let mut total_reference = 0u64;
    for seed in seeds {
        let mut rng = Pcg32::seeded(0xA7 + seed);
        let rows = 60 + 20 * (seed as usize % 5);
        let cols = 3 + seed as usize % 4;
        let ds = random_dataset(&mut rng, rows, cols);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);

        let reference = solve_odm_dual(&view, kernel, &p, None, &reference_budget);
        let shrunk = solve_odm_dual(&view, kernel, &p, None, &shrunk_budget);
        assert!(reference.stats.converged, "seed {seed}: reference did not converge");
        assert!(shrunk.stats.converged, "seed {seed}: shrunk did not converge");

        // Same objective within the solve tolerance.
        let rel = (reference.stats.objective - shrunk.stats.objective).abs()
            / (1.0 + reference.stats.objective.abs());
        assert!(
            rel < 1e-4,
            "seed {seed}: objective drift {rel} (ref {} vs shrunk {})",
            reference.stats.objective,
            shrunk.stats.objective
        );

        // Identical support set: the strictly convex dual has a unique
        // optimum, so coefficients must agree coordinate-wise and the
        // support sets must match at the eps scale.
        let g_ref = reference.gamma();
        let g_shr = shrunk.gamma();
        let mut s_ref: Vec<usize> = Vec::new();
        let mut s_shr: Vec<usize> = Vec::new();
        for i in 0..rows {
            assert!(
                (g_ref[i] - g_shr[i]).abs() < 1e-3,
                "seed {seed}: gamma[{i}] {} vs {}",
                g_ref[i],
                g_shr[i]
            );
            if g_ref[i].abs() > 1e-3 {
                s_ref.push(i);
            }
            if g_shr[i].abs() > 1e-3 {
                s_shr.push(i);
            }
        }
        assert_eq!(s_ref, s_shr, "seed {seed}: support sets differ");

        // Never (materially) more updates than the reference, per seed.
        assert!(
            shrunk.stats.updates <= reference.stats.updates + reference.stats.updates / 50,
            "seed {seed}: shrunk spent {} updates vs reference {}",
            shrunk.stats.updates,
            reference.stats.updates
        );
        assert!(shrunk.stats.shrink_ratio > 0.0, "seed {seed}: shrinking never engaged");
        assert_eq!(reference.stats.shrink_ratio, 0.0);
        total_shrunk += shrunk.stats.updates;
        total_reference += reference.stats.updates;
    }
    // Measurably fewer updates in aggregate (prototyped margin ≈ 15-20%).
    assert!(
        total_shrunk * 100 < total_reference * 95,
        "aggregate updates not reduced: shrunk {total_shrunk} vs reference {total_reference}"
    );
}

#[test]
fn shrink_matches_noshrink_rbf_kernel_path() {
    check_odm_equivalence(&KernelKind::Rbf { gamma: 1.0 }, 0..6);
}

#[test]
fn shrink_matches_noshrink_linear_path() {
    check_odm_equivalence(&KernelKind::Linear, 0..4);
}

#[test]
fn ordered_sweeps_match_reference_objective() {
    // The greedy second-order ordered sweeps are an equivalence-preserving
    // reordering: same unique optimum, converged to the same tolerance.
    let p = params();
    for seed in 0..3u64 {
        let mut rng = Pcg32::seeded(0x0D + seed);
        let ds = random_dataset(&mut rng, 90, 4);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let k = KernelKind::Rbf { gamma: 1.0 };
        let reference =
            solve_odm_dual(&view, &k, &p, None, &SolveBudget { shrink: false, ..tight() });
        let ordered = solve_odm_dual(
            &view,
            &k,
            &p,
            None,
            &SolveBudget { ordered_every: 4, ..tight() },
        );
        assert!(ordered.stats.converged);
        let rel = (reference.stats.objective - ordered.stats.objective).abs()
            / (1.0 + reference.stats.objective.abs());
        assert!(rel < 1e-4, "seed {seed}: ordered drifted {rel}");
    }
}

#[test]
fn svm_shrink_matches_reference_objective_and_box() {
    for seed in 0..4u64 {
        let mut rng = Pcg32::seeded(0xB0 + seed);
        let ds = random_dataset(&mut rng, 70 + 20 * (seed as usize % 3), 3);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let k = KernelKind::Rbf { gamma: 1.0 };
        let c = 1.0;
        let reference =
            solve_svm_dual(&view, &k, c, None, &SolveBudget { shrink: false, ..tight() });
        let shrunk = solve_svm_dual(&view, &k, c, None, &tight());
        assert!(reference.stats.converged && shrunk.stats.converged, "seed {seed}");
        let rel = (reference.stats.objective - shrunk.stats.objective).abs()
            / (1.0 + reference.stats.objective.abs());
        assert!(rel < 1e-3, "seed {seed}: objective drift {rel}");
        assert!(shrunk.gamma.iter().all(|g| (-1e-12..=c + 1e-12).contains(g)));
        assert!(shrunk.stats.shrink_ratio > 0.0, "seed {seed}: no shrinking on SVM path");
    }
}

#[test]
fn warm_started_merge_solves_are_deterministic() {
    // Regression (ISSUE): SodmConfig::with_tree merge training — including
    // the shrinking solver's active-set resets at every warm-started merge —
    // must be bit-deterministic given a seed.
    let mut rng = Pcg32::seeded(0x5EED);
    let ds = random_dataset(&mut rng, 240, 4);
    let k = KernelKind::Rbf { gamma: 1.5 };
    let p = params();
    let cfg = SodmConfig::with_tree(2, 2, 6);
    let a = train_sodm_traced(&ds, &k, &p, &cfg, None);
    let b = train_sodm_traced(&ds, &k, &p, &cfg, None);
    assert_eq!(a.trace.len(), b.trace.len());
    for (la, lb) in a.trace.iter().zip(&b.trace) {
        assert_eq!(la.n_partitions, lb.n_partitions);
        assert_eq!(la.sweeps, lb.sweeps, "sweep counts must be reproducible");
        assert_eq!(la.updates, lb.updates, "update counts must be reproducible");
        assert_eq!(la.objective, lb.objective, "objectives must be bit-identical");
    }
    for i in 0..10 {
        let x = ds.row(i * 7 % ds.rows);
        assert_eq!(a.model.decision(x), b.model.decision(x));
    }
}

#[test]
fn telemetry_populated_and_internally_consistent() {
    let mut rng = Pcg32::seeded(0x7E1E);
    let ds = random_dataset(&mut rng, 120, 4);
    let idx = all_indices(&ds);
    let view = DataView::new(&ds, &idx);
    let k = KernelKind::Rbf { gamma: 1.0 };
    let p = params();
    let shrunk = solve_odm_dual(&view, &k, &p, None, &tight());
    let reference =
        solve_odm_dual(&view, &k, &p, None, &SolveBudget { shrink: false, ..tight() });

    for (name, s) in [("shrunk", &shrunk.stats), ("reference", &reference.stats)] {
        assert!(s.sweeps > 0, "{name}: sweeps unset");
        assert!(s.updates > 0, "{name}: updates unset");
        assert!((0.0..=1.0).contains(&s.cache_hit_rate), "{name}: hit rate {}", s.cache_hit_rate);
        assert!((0.0..1.0).contains(&s.shrink_ratio), "{name}: shrink ratio {}", s.shrink_ratio);
        assert!(s.converged);
        assert!(s.max_violation < 1e-5, "{name}: violation {}", s.max_violation);
    }
    // Internal consistency: the shrunk solve never reports more updates than
    // the unshrunk one on the same problem (eps-scale slack only).
    assert!(
        shrunk.stats.updates <= reference.stats.updates + reference.stats.updates / 50,
        "shrunk {} vs reference {}",
        shrunk.stats.updates,
        reference.stats.updates
    );
    assert_eq!(reference.stats.shrink_ratio, 0.0);
    assert!(shrunk.stats.shrink_ratio > 0.0);
    // An update requires a visit: shrink_ratio bounds visits from above.
    let visited_bound = (shrunk.stats.sweeps as u64) * 2 * (view.len() as u64);
    assert!(shrunk.stats.updates <= visited_bound);
}
