//! Sparse/dense equivalence properties — the contract of the CSR data path:
//! a `SparseDataset` and its densified twin must agree through libsvm I/O,
//! kernel evaluation, the DCD solvers, and the SVRG family.
//!
//! The exact-value fixtures draw feature values from {0.25, 0.5, 0.75, 1.0}
//! with few nonzeros per row, so every f32 sum along both code paths is
//! exact — kernel evaluations then agree bitwise and the solver equivalences
//! are tested at 1e-6 (far looser than observed).

use sodm::data::libsvm::{read_libsvm, read_libsvm_auto, write_libsvm_sparse, LoadedDataset};
use sodm::data::sparse::{SparseDataset, SparseSynthSpec};
use sodm::data::{identity_indices, DataView};
use sodm::kernel::KernelKind;
use sodm::odm::{train_exact_odm, OdmModel, OdmParams};
use sodm::qp::{solve_odm_dual, SolveBudget};
use sodm::svrg::{train_dsvrg, NativeGrad, SvrgConfig};
use sodm::util::rng::Pcg32;

/// CSR fixture whose values make every f32 sum exact (see module docs).
fn exact_value_fixture(rows: usize, cols: usize, nnz_per_row: usize, seed: u64) -> SparseDataset {
    let vals = [0.25f32, 0.5, 0.75, 1.0];
    let mut rng = Pcg32::seeded(seed);
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut y = Vec::new();
    for _ in 0..rows {
        let mut ids = rng.sample_indices(cols, nnz_per_row.min(cols));
        ids.sort_unstable();
        for id in ids {
            indices.push(id as u32);
            values.push(vals[rng.gen_range(vals.len())]);
        }
        indptr.push(indices.len());
        y.push(if rng.gen_bool(0.5) { 1.0 } else { -1.0 });
    }
    SparseDataset::new("exact", indptr, indices, values, y, cols)
}

#[test]
fn libsvm_round_trip_preserves_sparse_and_dense_twins() {
    let sp = SparseSynthSpec::new(80, 120, 0.08, 11).generate();
    let dir = sodm::util::temp_dir("sparse-equiv");
    let p = dir.join("rt.libsvm");
    write_libsvm_sparse(&sp, &p).unwrap();
    // sparse reader round-trips the CSR structure exactly
    let back = sodm::data::libsvm::read_libsvm_sparse(&p, sp.cols).unwrap();
    assert_eq!(back.indptr, sp.indptr);
    assert_eq!(back.indices, sp.indices);
    assert_eq!(back.values, sp.values);
    assert_eq!(back.y, sp.y);
    // dense reader agrees with the densified twin cell for cell
    let dense = read_libsvm(&p, sp.cols).unwrap();
    let twin = sp.to_dense();
    assert_eq!(dense.x, twin.x);
    assert_eq!(dense.y, twin.y);
    // the auto loader keeps this 8%-dense file in CSR
    assert!(matches!(
        read_libsvm_auto(&p, sp.cols).unwrap(),
        LoadedDataset::Sparse(_)
    ));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn kernel_evaluations_agree_across_backings() {
    let sp = exact_value_fixture(60, 48, 6, 3);
    let dense = sp.to_dense();
    let mut rng = Pcg32::seeded(7);
    for kernel in [KernelKind::Linear, KernelKind::Rbf { gamma: 0.7 }] {
        for _ in 0..200 {
            let (i, j) = (rng.gen_range(sp.rows), rng.gen_range(sp.rows));
            let ks = kernel.eval_rr(sp.row_ref(i), sp.row_ref(j));
            let kd = kernel.eval(dense.row(i), dense.row(j));
            let km = kernel.eval_rr(sp.row_ref(i), sodm::data::RowRef::Dense(dense.row(j)));
            assert!((ks - kd).abs() < 1e-6, "{kernel:?} ({i},{j}): {ks} vs {kd}");
            assert!((km - kd).abs() < 1e-6, "{kernel:?} mixed ({i},{j}): {km} vs {kd}");
        }
    }
}

#[test]
fn odm_dual_solve_agrees_between_backings() {
    let sp = exact_value_fixture(90, 40, 8, 17);
    let dense = sp.to_dense();
    let sp_idx = identity_indices(sp.rows);
    let d_idx = identity_indices(dense.rows);
    let sv = DataView::sparse(&sp, &sp_idx);
    let dv = DataView::new(&dense, &d_idx);
    let params = OdmParams { lambda: 8.0, theta: 0.3, upsilon: 0.5 };
    let budget = SolveBudget { eps: 1e-7, max_sweeps: 4000, ..SolveBudget::default() };
    for kernel in [KernelKind::Rbf { gamma: 0.5 }, KernelKind::Linear] {
        let ss = solve_odm_dual(&sv, &kernel, &params, None, &budget);
        let sd = solve_odm_dual(&dv, &kernel, &params, None, &budget);
        let rel = (ss.stats.objective - sd.stats.objective).abs()
            / (1.0 + sd.stats.objective.abs());
        assert!(
            rel < 1e-6,
            "{kernel:?}: objectives {} vs {} (rel {rel})",
            ss.stats.objective,
            sd.stats.objective
        );
        // decision functions agree on every training row
        let ms = OdmModel::from_dual(&sv, &kernel, &ss.gamma());
        let md = OdmModel::from_dual(&dv, &kernel, &sd.gamma());
        for i in 0..sp.rows {
            let (a, b) = (ms.decision_rr(sp.row_ref(i)), md.decision(dense.row(i)));
            assert!(
                (a - b).abs() < 1e-6 * (1.0 + b.abs()),
                "{kernel:?} row {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn dsvrg_epochs_agree_between_backings() {
    // Same seeds + exact-value features: the sparse lazy iterate and the
    // dense eager iterate may differ only through the closed-form decay
    // (powi vs repeated multiplication), orders of magnitude below 1e-6.
    let sp = exact_value_fixture(200, 50, 7, 23);
    let dense = sp.to_dense();
    let params = OdmParams::default();
    let cfg = SvrgConfig { epochs: 3, partitions: 4, ..Default::default() };
    let grad = NativeGrad { workers: 2 };
    let rs = train_dsvrg(&sp, &params, &cfg, None, &grad);
    let rd = train_dsvrg(&dense, &params, &cfg, None, &grad);
    let (OdmModel::Linear { w: ws }, OdmModel::Linear { w: wd }) = (&rs.model, &rd.model)
    else {
        panic!("linear models expected")
    };
    for (j, (a, b)) in ws.iter().zip(wd).enumerate() {
        assert!((a - b).abs() < 1e-6 * (1.0 + b.abs()), "w[{j}]: {a} vs {b}");
    }
    assert_eq!(rs.checkpoints.len(), rd.checkpoints.len());
}

#[test]
fn highdim_sparse_loads_and_trains_in_o_nnz() {
    // The acceptance workload: 10k rows x 100k features at 0.1% density.
    // Dense storage would need 10_000 * 100_000 * 4 B = 4 GB — this test
    // passing at all is the O(nnz) memory proof (CSR holds ~1M nonzeros).
    let spec = SparseSynthSpec::new(10_000, 100_000, 0.001, 41);
    let ds = spec.generate();
    assert_eq!(ds.rows, 10_000);
    assert_eq!(ds.cols, 100_000);
    let cells = ds.rows * ds.cols;
    assert!(ds.nnz() * 100 < cells, "nnz {} must be ~0.1% of {cells}", ds.nnz());
    let (train, test) = ds.split(0.8, 5);

    // Linear path: DSVRG over the full split, O(nnz) per step.
    let run = train_dsvrg(
        &train,
        &OdmParams::default(),
        &SvrgConfig { epochs: 3, partitions: 4, ..Default::default() },
        None,
        &NativeGrad { workers: 2 },
    );
    let lin_acc = run.model.accuracy(&test);
    assert!(lin_acc > 0.8, "high-dim linear DSVRG accuracy {lin_acc}");

    // Kernel path smoke: rbf SODM on a subset (Gram work is O(m²·nnz)).
    let sub_idx: Vec<usize> = (0..1_500).collect();
    let sub = train.subset(&sub_idx);
    let gamma = 1.0 / (0.74 * 0.001 * 100_000.0);
    let model = sodm::sodm::train_sodm(
        &sub,
        &KernelKind::Rbf { gamma: gamma as f32 },
        &OdmParams::default(),
        &sodm::sodm::SodmConfig {
            budget: SolveBudget { max_sweeps: 15, ..SolveBudget::default() },
            final_exact: false,
            ..sodm::sodm::SodmConfig::with_tree(4, 2, 8)
        },
        None,
    );
    assert!(matches!(model, OdmModel::SparseKernel { .. }));
    // near-diagonal Gram at this dimensionality: assert the path runs and
    // the model is not degenerate rather than a tight accuracy bar
    let rbf_acc = model.accuracy(&test);
    assert!(rbf_acc > 0.4, "high-dim rbf SODM smoke accuracy {rbf_acc}");
    assert!(model.support_size() > 0);
}

#[test]
fn exact_odm_sparse_equals_dense_on_synth() {
    // End-to-end equivalence on generator output (arbitrary f32 values):
    // tight-eps solves land both backings at the unique optimum.
    let sp = SparseSynthSpec::new(120, 80, 0.1, 29).generate();
    let dense = sp.to_dense();
    let params = OdmParams::default();
    let budget = SolveBudget { eps: 1e-7, max_sweeps: 4000, ..SolveBudget::default() };
    let kernel = KernelKind::Linear;
    let ms = train_exact_odm(&sp, &kernel, &params, &budget);
    let md = train_exact_odm(&dense, &kernel, &params, &budget);
    for i in 0..sp.rows {
        let (a, b) = (ms.decision_rr(sp.row_ref(i)), md.decision(dense.row(i)));
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "row {i}: {a} vs {b}");
    }
}
