//! Facade acceptance tests: `TrainSpec` validation error paths, facade ==
//! direct-trainer equivalence pinned at 1e-12, versioned artifact
//! round-trips, and the committed v0 model-JSON fixtures proving the
//! backward-compatibility migration shim is bit-exact.

use std::path::PathBuf;

use sodm::api::{self, Artifact, ArtifactModel, Method, OvrOptions, SpecError, TrainSpec};
use sodm::data::synth::SynthSpec;
use sodm::data::RowRef;
use sodm::kernel::KernelKind;
use sodm::multiclass::{MulticlassModel, MulticlassSynthSpec};
use sodm::odm::{OdmModel, OdmParams};
use sodm::qp::SolveBudget;
use sodm::serve::ServeConfig;
use sodm::sodm::{train_sodm, SodmConfig};
use sodm::svrg::{train_dsvrg, NativeGrad, SvrgConfig};
use sodm::util::json::Json;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures").join(name)
}

fn fixture_json(name: &str) -> Json {
    let text = std::fs::read_to_string(fixture_path(name)).expect("fixture readable");
    Json::parse(&text).expect("fixture parses")
}

fn dense_fixture(rows: usize, seed: u64) -> sodm::data::Dataset {
    let mut s = SynthSpec::named("svmguide1", 0.02, seed);
    s.rows = rows;
    s.generate()
}

fn assert_close_1e12(a: f64, b: f64, what: &str) {
    assert!((a - b).abs() <= 1e-12 * (1.0 + b.abs()), "{what}: {a} vs {b}");
}

// --- TrainSpec validation error paths ------------------------------------

#[test]
fn spec_validation_reports_typed_errors() {
    let rbf = KernelKind::Rbf { gamma: 0.5 };
    // bad method x kernel combos: the whole gradient family is linear-only
    for m in [Method::Dsvrg, Method::Svrg, Method::Csvrg] {
        assert_eq!(
            TrainSpec::new(m).kernel(rbf).build().unwrap_err(),
            SpecError::LinearOnly { method: m.name() }
        );
    }
    // zero workers
    assert_eq!(
        TrainSpec::new(Method::Sodm).kernel(rbf).workers(0).build().unwrap_err(),
        SpecError::ZeroWorkers
    );
    // negative / non-finite gamma
    assert_eq!(
        TrainSpec::new(Method::Sodm).kernel(KernelKind::Rbf { gamma: -1.0 }).build().unwrap_err(),
        SpecError::BadGamma { gamma: -1.0 }
    );
    assert!(matches!(
        TrainSpec::new(Method::Sodm)
            .kernel(KernelKind::Rbf { gamma: f32::NAN })
            .build()
            .unwrap_err(),
        SpecError::BadGamma { .. }
    ));
    // hyperparameter ranges
    let with_params = |p: OdmParams| TrainSpec::new(Method::ExactOdm).kernel(rbf).params(p);
    assert_eq!(
        with_params(OdmParams { lambda: 0.0, ..OdmParams::default() }).build().unwrap_err(),
        SpecError::BadLambda { lambda: 0.0 }
    );
    assert_eq!(
        with_params(OdmParams { theta: 1.0, ..OdmParams::default() }).build().unwrap_err(),
        SpecError::BadTheta { theta: 1.0 }
    );
    assert_eq!(
        with_params(OdmParams { upsilon: 0.0, ..OdmParams::default() }).build().unwrap_err(),
        SpecError::BadUpsilon { upsilon: 0.0 }
    );
    // solver budget
    let zero_sweeps = SolveBudget { max_sweeps: 0, ..SolveBudget::default() };
    assert_eq!(
        TrainSpec::new(Method::Sodm).kernel(rbf).budget(zero_sweeps).build().unwrap_err(),
        SpecError::ZeroSweeps
    );
    let bad_eps = SolveBudget { eps: 0.0, ..SolveBudget::default() };
    assert_eq!(
        TrainSpec::new(Method::Sodm).kernel(rbf).budget(bad_eps).build().unwrap_err(),
        SpecError::BadEps { eps: 0.0 }
    );
    // tree / gradient shape knobs
    assert_eq!(
        TrainSpec::new(Method::Sodm).kernel(rbf).tree(1, 2, 8).build().unwrap_err(),
        SpecError::MergeArity { p: 1 }
    );
    assert_eq!(
        TrainSpec::new(Method::Sodm).kernel(rbf).tree(4, 2, 0).build().unwrap_err(),
        SpecError::ZeroStratums
    );
    assert_eq!(
        TrainSpec::new(Method::Dsvrg).epochs(0).build().unwrap_err(),
        SpecError::ZeroEpochs
    );
    assert_eq!(
        TrainSpec::new(Method::Dsvrg).partitions(0).build().unwrap_err(),
        SpecError::ZeroPartitions
    );
    assert_eq!(
        TrainSpec::new(Method::Csvrg).coreset(0).build().unwrap_err(),
        SpecError::ZeroCoreset
    );
    // SVM local solver only applies to the baseline meta-methods
    assert_eq!(
        TrainSpec::new(Method::Sodm)
            .kernel(rbf)
            .solver(api::LocalSolver::Svm { c: 1.0 })
            .build()
            .unwrap_err(),
        SpecError::SvmSolverUnsupported { method: "sodm" }
    );
    assert_eq!(
        TrainSpec::new(Method::Cascade)
            .kernel(rbf)
            .solver(api::LocalSolver::Svm { c: 0.0 })
            .build()
            .unwrap_err(),
        SpecError::BadSvmC { c: 0.0 }
    );
    // multiclass requires the exact ODM per-class solver
    assert_eq!(
        TrainSpec::new(Method::Sodm).kernel(rbf).multiclass(OvrOptions::default()).build().err(),
        Some(SpecError::MulticlassUnsupported { method: "sodm" })
    );
    // unknown method names are typed too
    assert_eq!(
        Method::parse("sodm2").unwrap_err(),
        SpecError::UnknownMethod { given: "sodm2".into() }
    );
    // and the canonical good specs build
    assert!(TrainSpec::new(Method::Sodm).kernel(rbf).build().is_ok());
    assert!(TrainSpec::new(Method::Dsvrg).build().is_ok());
    assert!(TrainSpec::new(Method::ExactOdm).multiclass(OvrOptions::default()).build().is_ok());
}

#[test]
fn binary_spec_rejects_multiclass_data_and_vice_versa() {
    let mc = MulticlassSynthSpec::new(3, 60, 4, 3).generate();
    let bin = dense_fixture(60, 3);
    let bin_spec = TrainSpec::new(Method::ExactOdm).kernel(KernelKind::Linear).build().unwrap();
    assert!(api::train(&bin_spec, &mc).is_err(), "binary spec must reject multiclass data");
    let mc_spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Linear)
        .multiclass(OvrOptions::default())
        .build()
        .unwrap();
    assert!(api::train(&mc_spec, &bin).is_err(), "multiclass spec must reject binary rows");
}

// --- facade == direct trainer equivalence at 1e-12 ------------------------

#[test]
fn facade_matches_direct_sodm_at_1e12() {
    let ds = dense_fixture(240, 11);
    let kernel = KernelKind::Rbf { gamma: 1.5 };
    let params = OdmParams::default();
    let spec = TrainSpec::new(Method::Sodm)
        .kernel(kernel)
        .params(params)
        .tree(2, 2, 6)
        .seed(17)
        .build()
        .unwrap();
    let facade = api::train(&spec, &ds).unwrap();
    let direct = train_sodm(
        &ds,
        &kernel,
        &params,
        &SodmConfig { seed: 17, ..SodmConfig::with_tree(2, 2, 6) },
        None,
    );
    let got = facade.decisions(&ds).unwrap();
    let want = direct.decisions(&ds);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_close_1e12(*a, *b, &format!("sodm decision row {i}"));
    }
    assert_eq!(facade.support_size(), direct.support_size());
    assert_eq!(facade.meta.method, "sodm");
    assert!(facade.meta.sweeps > 0, "sodm telemetry must aggregate into the artifact");
}

#[test]
fn facade_matches_direct_dsvrg_at_1e12() {
    let ds = dense_fixture(300, 19);
    let params = OdmParams::default();
    let workers = 2;
    let spec = TrainSpec::new(Method::Dsvrg)
        .params(params)
        .workers(workers)
        .epochs(3)
        .partitions(4)
        .stratums(8)
        .seed(23)
        .build()
        .unwrap();
    let facade = api::train(&spec, &ds).unwrap();
    let direct = train_dsvrg(
        &ds,
        &params,
        &SvrgConfig { epochs: 3, partitions: 4, seed: 23, ..SvrgConfig::default() },
        None,
        &NativeGrad { workers },
    )
    .model;
    let got = facade.decisions(&ds).unwrap();
    let want = direct.decisions(&ds);
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_close_1e12(*a, *b, &format!("dsvrg decision row {i}"));
    }
}

#[test]
fn facade_routes_linear_sodm_to_dsvrg() {
    // `sodm` + linear kernel is the paper's §3.3 routing: the facade must
    // produce the DSVRG accelerator's model, not a hierarchical merge.
    let ds = dense_fixture(300, 19);
    let spec = TrainSpec::new(Method::Sodm)
        .workers(2)
        .epochs(3)
        .partitions(4)
        .seed(23)
        .build()
        .unwrap();
    let via_sodm = api::train(&spec, &ds).unwrap();
    let spec_dsvrg = TrainSpec::new(Method::Dsvrg)
        .workers(2)
        .epochs(3)
        .partitions(4)
        .seed(23)
        .build()
        .unwrap();
    let via_dsvrg = api::train(&spec_dsvrg, &ds).unwrap();
    let (a, b) = (via_sodm.decisions(&ds).unwrap(), via_dsvrg.decisions(&ds).unwrap());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_close_1e12(*x, *y, &format!("linear-sodm routing row {i}"));
    }
}

#[test]
fn facade_matches_direct_exact_odm_at_1e12() {
    let ds = dense_fixture(150, 29);
    let kernel = KernelKind::Rbf { gamma: 2.0 };
    let spec = TrainSpec::new(Method::ExactOdm).kernel(kernel).build().unwrap();
    let facade = api::train(&spec, &ds).unwrap();
    let direct =
        sodm::odm::train_exact_odm(&ds, &kernel, &OdmParams::default(), &SolveBudget::default());
    let got = facade.decisions(&ds).unwrap();
    let want = direct.decisions(&ds);
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_close_1e12(*a, *b, &format!("exact odm decision row {i}"));
    }
}

// --- committed v0 fixtures: migration shim is bit-exact -------------------

#[test]
fn v0_dense_rbf_fixture_loads_and_serves_identically() {
    let art = Artifact::load(fixture_path("v0_dense_rbf.json")).unwrap();
    let direct = OdmModel::from_json(&fixture_json("v0_dense_rbf.json")).unwrap();
    let ArtifactModel::Binary(migrated) = &art.model else { panic!("binary fixture") };
    assert_eq!(
        migrated.to_json().to_string(),
        direct.to_json().to_string(),
        "v0 migration must be bit-exact"
    );
    assert_eq!(art.meta.method, "unknown", "v0 artifacts carry no training metadata");
    assert_eq!(art.meta.kernel, KernelKind::Rbf { gamma: 0.5 });
    let probes: [[f32; 3]; 3] = [[0.1, 0.5, -0.2], [0.0, 0.0, 0.0], [1.0, -1.0, 0.25]];
    let h = art.serve(ServeConfig::default()).unwrap();
    for x in &probes {
        let want = direct.decision(x);
        assert_eq!(migrated.decision(x), want, "migrated model must score bit-identically");
        assert_close_1e12(h.score(x).unwrap(), want, "served v0 dense decision");
    }
    h.stop();
}

#[test]
fn v0_sparse_rbf_fixture_loads_and_serves_identically() {
    let art = Artifact::load(fixture_path("v0_sparse_rbf.json")).unwrap();
    let direct = OdmModel::from_json(&fixture_json("v0_sparse_rbf.json")).unwrap();
    let ArtifactModel::Binary(migrated) = &art.model else { panic!("binary fixture") };
    assert!(matches!(migrated, OdmModel::SparseKernel { .. }), "CSR support vectors survive");
    assert_eq!(migrated.to_json().to_string(), direct.to_json().to_string());
    let h = art.serve(ServeConfig::default()).unwrap();
    let check = |indices: &[u32], values: &[f32]| {
        let rr = RowRef::Sparse { indices, values, cols: 6 };
        let want = direct.decision_rr(rr);
        assert_eq!(migrated.decision_rr(rr), want);
        assert_close_1e12(h.score_sparse(indices, values).unwrap(), want, "served v0 CSR");
    };
    check(&[0, 3], &[1.0, -0.5]);
    check(&[1, 2, 5], &[0.25, -1.0, 2.0]);
    check(&[], &[]);
    h.stop();
}

#[test]
fn v0_multiclass_fixture_loads_and_serves_identically() {
    let art = Artifact::load(fixture_path("v0_multiclass_ovr.json")).unwrap();
    let direct = MulticlassModel::from_json(&fixture_json("v0_multiclass_ovr.json")).unwrap();
    let migrated = art.as_multiclass().expect("multiclass fixture");
    assert_eq!(migrated.to_json().to_string(), direct.to_json().to_string());
    assert_eq!(art.n_classes(), Some(3));
    let probes: [[f32; 3]; 3] = [[0.1, 0.2, 0.3], [0.0, 0.0, 0.0], [-0.5, 1.0, 0.25]];
    let h = art.serve(ServeConfig::default()).unwrap();
    for x in &probes {
        let want: Vec<f64> = direct.models.iter().map(|m| m.decision(x)).collect();
        let mut want_argmax = 0;
        for (c, s) in want.iter().enumerate() {
            if *s > want[want_argmax] {
                want_argmax = c;
            }
        }
        let got = h.score_multiclass(x).unwrap();
        assert_eq!(got.argmax, want_argmax);
        for (c, (a, b)) in got.scores.iter().zip(&want).enumerate() {
            assert_close_1e12(*a, *b, &format!("served v0 multiclass class {c}"));
        }
    }
    h.stop();
}

// --- versioned envelope round-trips ---------------------------------------

#[test]
fn trained_artifact_round_trips_through_v1_json() {
    let ds = dense_fixture(120, 31);
    let spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma: 1.0 })
        .seed(5)
        .build()
        .unwrap();
    let art = api::train(&spec, &ds).unwrap();
    let dir = sodm::util::temp_dir("api-v1");
    let path = dir.join("artifact.json");
    art.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed.req("format_version").unwrap().as_usize().unwrap(), api::FORMAT_VERSION);
    let back = Artifact::load(&path).unwrap();
    assert_eq!(art.to_json().to_string(), back.to_json().to_string(), "round trip is bit-exact");
    assert_eq!(back.meta.method, "odm");
    assert_eq!(back.meta.sweeps, art.meta.sweeps);
    assert_eq!(back.meta.converged, art.meta.converged);
    let (a, b) = (art.decisions(&ds).unwrap(), back.decisions(&ds).unwrap());
    assert_eq!(a, b, "loaded artifact must score bit-identically");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn multiclass_artifact_round_trips_through_v1_json() {
    let ds = MulticlassSynthSpec::new(3, 90, 5, 21).generate();
    let spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma: 0.1 })
        .budget(SolveBudget { max_sweeps: 15, ..SolveBudget::default() })
        .multiclass(OvrOptions::default())
        .build()
        .unwrap();
    let run = api::train_run(&spec, &ds, None).unwrap();
    assert_eq!(run.class_stats.len(), 3, "per-class telemetry rides along");
    assert!(run.cache_hit_rate > 0.0, "shared Gram cache is the default");
    let dir = sodm::util::temp_dir("api-v1-mc");
    let path = dir.join("mc.json");
    run.artifact.save(&path).unwrap();
    let back = Artifact::load(&path).unwrap();
    assert_eq!(run.artifact.to_json().to_string(), back.to_json().to_string());
    let a = run.artifact.as_multiclass().unwrap().scores(ds.as_rows(), 2);
    let b = back.as_multiclass().unwrap().scores(ds.as_rows(), 2);
    assert_eq!(a, b, "multiclass scores are bitwise equal after the round trip");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn newer_format_versions_are_rejected() {
    let dir = sodm::util::temp_dir("api-future");
    let path = dir.join("future.json");
    std::fs::write(&path, r#"{"format_version":99,"model":{"kind":"linear","w":[1.0]}}"#).unwrap();
    let err = Artifact::load(&path).unwrap_err().to_string();
    assert!(err.contains("format_version 99"), "{err}");
    // an explicit version-0 envelope never existed: rejected with an
    // accurate message (v0 files are bare payloads without the field)
    std::fs::write(&path, r#"{"format_version":0,"model":{"kind":"linear","w":[1.0]}}"#).unwrap();
    let err = Artifact::load(&path).unwrap_err().to_string();
    assert!(err.contains("format_version 0"), "{err}");
    assert!(!err.contains("newer"), "v0 envelope must not claim to be newer: {err}");
    std::fs::remove_dir_all(dir).ok();
}
