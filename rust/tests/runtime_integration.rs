//! PJRT runtime integration: the AOT Pallas artifacts must agree with the
//! rust-native compute to f32 tolerance. Requires `make artifacts` (tests
//! are skipped with a notice when the manifest is absent).

use sodm::data::{all_indices, synth::SynthSpec, DataView};
use sodm::kernel::{signed_row, KernelKind};
use sodm::odm::{OdmModel, OdmParams};
use sodm::runtime::{XlaEngine, XlaGrad};
use sodm::svrg::{grad_sum_native, train_dsvrg, GradSource, NativeGrad, SvrgConfig};

fn engine() -> Option<XlaEngine> {
    let e = XlaEngine::load_default();
    if e.is_none() {
        eprintln!("SKIP: artifacts/manifest.json not found — run `make artifacts`");
    }
    e
}

fn fixture(rows: usize, name: &str) -> sodm::data::Dataset {
    let mut s = SynthSpec::named(name, 0.01, 77);
    s.rows = rows;
    s.generate()
}

#[test]
fn gram_block_matches_native() {
    let Some(engine) = engine() else { return };
    let ds = fixture(200, "phishing");
    let idx = all_indices(&ds);
    let view = DataView::new(&ds, &idx);
    let gamma = 0.8f32;
    let kernel = KernelKind::Rbf { gamma };
    // native rows
    let mut native = vec![0.0f32; 200 * 200];
    for i in 0..200 {
        let row = &mut native[i * 200..(i + 1) * 200];
        signed_row(&view, &kernel, i, row);
    }
    // artifact block (200x200 fits one 256x256 tile)
    let block = engine
        .rbf_gram_block(&ds.x, &ds.y, &ds.x, &ds.y, ds.cols, gamma)
        .expect("gram artifact");
    assert_eq!(block.len(), 200 * 200);
    let mut worst = 0.0f32;
    for (a, b) in block.iter().zip(&native) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 2e-4, "gram mismatch {worst}");
}

#[test]
fn odm_grad_artifact_matches_native() {
    let Some(engine) = engine() else { return };
    let ds = fixture(1500, "cod-rna"); // > grad_b forces multi-batch looping
    let idx = all_indices(&ds);
    let view = DataView::new(&ds, &idx);
    let params = OdmParams { lambda: 32.0, theta: 0.25, upsilon: 0.5 };
    let mut w = vec![0.0f64; ds.cols];
    for (j, wj) in w.iter_mut().enumerate() {
        *wj = ((j as f64) * 0.37).sin() * 0.5;
    }
    let (g_native, l_native) = grad_sum_native(&w, &view, &params, 1);
    let xg = XlaGrad { engine };
    let (g_xla, l_xla) = xg.grad_sum(&w, &view, &params);
    assert_eq!(g_native.len(), g_xla.len());
    for (a, b) in g_native.iter().zip(&g_xla) {
        assert!(
            (a - b).abs() < 1e-2 * (1.0 + a.abs()),
            "grad mismatch {a} vs {b}"
        );
    }
    assert!(
        (l_native - l_xla).abs() < 1e-2 * (1.0 + l_native.abs()),
        "loss mismatch {l_native} vs {l_xla}"
    );
}

#[test]
fn rbf_decisions_match_model() {
    let Some(engine) = engine() else { return };
    let ds = fixture(300, "svmguide1");
    let (train, test) = ds.split(0.8, 1);
    let kernel = KernelKind::Rbf { gamma: 1.2 };
    let model = sodm::odm::train_exact_odm(
        &train,
        &kernel,
        &OdmParams::default(),
        &Default::default(),
    );
    let OdmModel::Kernel { sv_x, coef, cols, .. } = &model else { panic!() };
    let got = engine
        .rbf_decisions(sv_x, coef, &test.x, *cols, 1.2)
        .expect("decision artifact");
    let want = model.decisions(&test);
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn linear_decisions_match() {
    let Some(engine) = engine() else { return };
    let ds = fixture(300, "svmguide1");
    let w: Vec<f64> = (0..ds.cols).map(|j| (j as f64 + 1.0) * 0.3).collect();
    let got = engine.linear_decisions(&w, &ds.x, ds.cols).expect("linear artifact");
    for (i, g) in got.iter().enumerate() {
        let want: f64 = w.iter().zip(ds.row(i)).map(|(a, b)| a * *b as f64).sum();
        assert!((g - want).abs() < 1e-3 * (1.0 + want.abs()), "{g} vs {want}");
    }
}

#[test]
fn dsvrg_with_xla_grad_matches_native_grad() {
    // The full Algorithm 2 run with the Pallas artifact as the gradient
    // source must land at (numerically) the same model as the native run.
    let Some(engine) = engine() else { return };
    let ds = fixture(800, "svmguide1");
    let params = OdmParams::default();
    let cfg = SvrgConfig { epochs: 2, partitions: 4, ..Default::default() };
    let native = train_dsvrg(&ds, &params, &cfg, None, &NativeGrad { workers: 1 });
    let xla = train_dsvrg(&ds, &params, &cfg, None, &XlaGrad { engine });
    let (OdmModel::Linear { w: wn }, OdmModel::Linear { w: wx }) = (&native.model, &xla.model)
    else {
        panic!()
    };
    let mut worst = 0.0f64;
    for (a, b) in wn.iter().zip(wx) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 1e-2, "DSVRG weight divergence {worst}");
    assert_eq!(native.checkpoints.len(), xla.checkpoints.len());
}

#[test]
fn unknown_artifact_errors_cleanly() {
    let Some(engine) = engine() else { return };
    let err = engine.execute("no_such_artifact", vec![]).unwrap_err();
    assert!(format!("{err:#}").contains("unknown artifact"));
}

#[test]
fn oversized_gram_request_rejected() {
    let Some(engine) = engine() else { return };
    let ds = fixture(300, "svmguide1"); // 300 > 256 tile
    let err = engine
        .rbf_gram_block(&ds.x, &ds.y, &ds.x, &ds.y, ds.cols, 0.5)
        .unwrap_err();
    assert!(format!("{err:#}").contains("exceeds"));
}
