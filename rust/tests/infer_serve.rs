//! Compiled-plan and serving-runtime equivalence tests (ISSUE 3 acceptance
//! fixtures): `ScoringPlan` must agree with the scalar row-at-a-time
//! reference at 1e-6 on dense and CSR models, and the sharded multi-worker
//! server must return plan-equivalent decisions under heavy concurrent
//! mixed (dense + CSR) load with reconciling metrics.

use std::sync::atomic::Ordering;

use sodm::data::sparse::{SparseDataset, SparseSynthSpec};
use sodm::data::synth::SynthSpec;
use sodm::data::RowRef;
use sodm::infer::{decision_reference, ScoringPlan, ShardedPlan};
use sodm::kernel::KernelKind;
use sodm::odm::{train_exact_odm, OdmModel, OdmParams};
use sodm::qp::SolveBudget;
use sodm::serve::{serve, Backend, ServeConfig};

fn dense_fixture() -> (OdmModel, sodm::data::Dataset) {
    let mut spec = SynthSpec::named("svmguide1", 0.02, 11);
    spec.rows = 300;
    let ds = spec.generate();
    let model = train_exact_odm(
        &ds,
        &KernelKind::Rbf { gamma: 1.5 },
        &OdmParams::default(),
        &SolveBudget { max_sweeps: 60, ..SolveBudget::default() },
    );
    (model, ds)
}

fn sparse_fixture() -> (OdmModel, SparseDataset) {
    let sp = SparseSynthSpec::new(250, 1500, 0.02, 13).generate();
    let model = train_exact_odm(
        &sp,
        &KernelKind::Rbf { gamma: 0.4 },
        &OdmParams::default(),
        &SolveBudget { max_sweeps: 30, ..SolveBudget::default() },
    );
    (model, sp)
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() < 1e-6 * (1.0 + b.abs())
}

#[test]
fn plan_matches_reference_on_dense_fixture() {
    let (model, ds) = dense_fixture();
    let plan = ScoringPlan::compile(&model);
    let refs: Vec<RowRef> = (0..ds.rows).map(|i| RowRef::Dense(ds.row(i))).collect();
    let mut block = vec![0.0; refs.len()];
    plan.score_block(&refs, &mut block);
    for (i, got) in block.iter().enumerate() {
        let want = decision_reference(&model, refs[i]);
        assert!(close(*got, want), "row {i}: plan {got} vs reference {want}");
    }
    // model-level batch APIs route through the same plan
    let decisions = model.decisions(&ds);
    for (a, b) in decisions.iter().zip(&block) {
        assert!(close(*a, *b));
    }
}

#[test]
fn plan_matches_reference_on_csr_fixture() {
    let (model, sp) = sparse_fixture();
    assert!(matches!(model, OdmModel::SparseKernel { .. }));
    let plan = ScoringPlan::compile(&model);
    let refs: Vec<RowRef> = (0..sp.rows).map(|i| sp.row_ref(i)).collect();
    let mut block = vec![0.0; refs.len()];
    plan.score_block(&refs, &mut block);
    for (i, got) in block.iter().enumerate() {
        let want = decision_reference(&model, refs[i]);
        assert!(close(*got, want), "row {i}: plan {got} vs reference {want}");
    }
    // accuracy (plan-routed) equals the sign rule over the plan scores
    let right = block.iter().zip(&sp.y).filter(|(d, y)| (**d >= 0.0) == (**y > 0.0)).count();
    let want_acc = right as f64 / sp.rows as f64;
    assert!((model.accuracy(&sp) - want_acc).abs() < 1e-12);
}

#[test]
fn sharded_plans_agree_with_unsharded_across_worker_shard_grid() {
    let (model, ds) = dense_fixture();
    let plan = ScoringPlan::compile(&model);
    let refs: Vec<RowRef> = (0..32).map(|i| RowRef::Dense(ds.row(i))).collect();
    let mut want = vec![0.0; refs.len()];
    plan.score_block(&refs, &mut want);
    for shards in [2usize, 4, 9] {
        let sharded = ShardedPlan::compile(&model, shards);
        let mut got = vec![0.0; refs.len()];
        sharded.score_block(&refs, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "{shards} shards: {a} vs {b}");
        }
    }
}

/// Satellite: many client threads submitting dense + CSR requests
/// simultaneously against one sharded multi-worker server; every decision
/// must match the single-threaded plan at 1e-6 and the metrics must
/// reconcile with the submitted load.
#[test]
fn concurrent_mixed_serving_matches_plan_and_metrics_reconcile() {
    let (model, ds) = dense_fixture();
    let plan = ScoringPlan::compile(&model);
    let csr = SparseDataset::from_dense(&ds);
    let cfg = ServeConfig {
        workers: 4,
        shards: 3,
        max_wait: std::time::Duration::from_millis(5),
        ..ServeConfig::default()
    };
    let h = serve(model, Backend::Native, cfg).unwrap();
    let threads = 12usize;
    let per_thread = 24usize;
    std::thread::scope(|s| {
        for t in 0..threads {
            let h = h.clone();
            let (ds, csr, plan) = (&ds, &csr, &plan);
            s.spawn(move || {
                for r in 0..per_thread {
                    let i = (t * per_thread + r * 31) % ds.rows;
                    let (got, want) = if (t + r) % 2 == 0 {
                        let row = RowRef::Dense(ds.row(i));
                        (h.score(ds.row(i)).unwrap(), plan.score_rr(row))
                    } else {
                        let (lo, hi) = (csr.indptr[i], csr.indptr[i + 1]);
                        let got =
                            h.score_sparse(&csr.indices[lo..hi], &csr.values[lo..hi]).unwrap();
                        (got, plan.score_rr(csr.row_ref(i)))
                    };
                    assert!(close(got, want), "thread {t} req {r}: {got} vs {want}");
                }
            });
        }
    });
    let m = h.metrics();
    let total = (threads * per_thread) as u64;
    let requests = m.requests.load(Ordering::Relaxed);
    let batches = m.batches.load(Ordering::Relaxed);
    assert_eq!(requests, total, "every submitted request must be counted");
    assert!(batches >= 1, "at least one batch must have been dispatched");
    assert!(batches <= requests, "{batches} batches for {requests} requests");
    assert_eq!(m.latency.count(), total, "every reply must record a latency sample");
    let mean = m.mean_batch_size();
    assert!((mean * batches as f64 - requests as f64).abs() < 1e-6, "counts must reconcile");
    h.stop();
}

/// Satellite (ISSUE 4): degenerate shard shapes — more shards than support
/// vectors, exactly one SV, and shards == 1 — must all reduce to the
/// unsharded plan's decision at 1e-12.
#[test]
fn sharded_degenerate_shapes_match_unsharded_plan() {
    let (model, ds) = dense_fixture();
    let plan = ScoringPlan::compile(&model);
    let sv = plan.support_size();
    assert!(sv > 1, "fixture must have a real expansion");
    let refs: Vec<RowRef> = (0..24).map(|i| RowRef::Dense(ds.row(i))).collect();
    let mut want = vec![0.0; refs.len()];
    plan.score_block(&refs, &mut want);
    for shards in [1usize, sv, sv + 7, 10 * sv] {
        let sharded = ShardedPlan::compile(&model, shards);
        let compiled = sharded.num_shards();
        assert!(compiled <= sv, "{shards} shards requested, {compiled} compiled for {sv} SVs");
        assert_eq!(sharded.support_size(), sv);
        for s in 0..sharded.num_shards() {
            assert!(sharded.shard(s).support_size() >= 1, "no empty shards");
        }
        let mut got = vec![0.0; refs.len()];
        sharded.score_block(&refs, &mut got);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-12 * (1.0 + b.abs()),
                "{shards} shards, row {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn sharded_csr_degenerate_shapes_match_unsharded_plan() {
    let (model, sp) = sparse_fixture();
    let plan = ScoringPlan::compile(&model);
    let sv = plan.support_size();
    let refs: Vec<RowRef> = (0..16).map(|i| sp.row_ref(i)).collect();
    let mut want = vec![0.0; refs.len()];
    plan.score_block(&refs, &mut want);
    for shards in [1usize, sv, sv + 3] {
        let sharded = ShardedPlan::compile(&model, shards);
        assert!(sharded.num_shards() <= sv);
        let mut got = vec![0.0; refs.len()];
        sharded.score_block(&refs, &mut got);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-12 * (1.0 + b.abs()),
                "{shards} shards, row {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn single_support_vector_model_always_compiles_one_shard() {
    let m = OdmModel::Kernel {
        kernel: KernelKind::Rbf { gamma: 0.5 },
        sv_x: vec![0.25, -0.5],
        coef: vec![1.25],
        cols: 2,
    };
    let plan = ScoringPlan::compile(&m);
    let x = [0.1f32, 0.2];
    let want = plan.score_rr(RowRef::Dense(&x));
    for shards in [1usize, 2, 8] {
        let sharded = ShardedPlan::compile(&m, shards);
        assert_eq!(sharded.num_shards(), 1, "one SV cannot split");
        let mut got = [0.0f64];
        sharded.score_block(&[RowRef::Dense(&x)], &mut got);
        assert!((got[0] - want).abs() < 1e-12 * (1.0 + want.abs()));
    }
}

#[test]
fn csr_model_server_accepts_both_request_backings() {
    let (model, sp) = sparse_fixture();
    let plan = ScoringPlan::compile(&model);
    let dense = sp.to_dense();
    let cfg = ServeConfig { workers: 2, shards: 2, ..ServeConfig::default() };
    let h = serve(model, Backend::Native, cfg).unwrap();
    for i in 0..12 {
        let (lo, hi) = (sp.indptr[i], sp.indptr[i + 1]);
        let got_sparse = h.score_sparse(&sp.indices[lo..hi], &sp.values[lo..hi]).unwrap();
        let got_dense = h.score(dense.row(i)).unwrap();
        assert!(close(got_sparse, plan.score_rr(sp.row_ref(i))), "row {i} (csr)");
        assert!(close(got_dense, plan.score_rr(RowRef::Dense(dense.row(i)))), "row {i} (dense)");
    }
    h.stop();
}
