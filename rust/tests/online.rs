//! Integration tests for the online / streaming subsystem
//! ([`sodm::online`] + [`sodm::serve::serve_online`]): the drift contract
//! (prequential online accuracy must beat a frozen batch model after the
//! concept flips), bit-exact snapshot→restore through an artifact file on
//! disk, and snapshot-isolated serving — concurrent feedback updates must
//! never tear a served score.

use std::sync::Arc;

use sodm::api::{self, Artifact, Method, TrainSpec};
use sodm::data::Dataset;
use sodm::odm::OdmParams;
use sodm::online::{DriftStream, OnlineOdm, OnlineSlot};
use sodm::serve::{serve_online, ServeConfig};

fn params() -> OdmParams {
    OdmParams { lambda: 8.0, theta: 0.2, upsilon: 0.5 }
}

/// After the drift negates the concept, the frozen batch model collapses
/// while the online learner re-converges within ~1/eta steps — the gap on
/// identical post-drift rows is the whole point of streaming updates.
#[test]
fn online_learner_beats_frozen_batch_model_after_drift() {
    let (pre, post, cols) = (500usize, 500usize, 10usize);
    let mut stream = DriftStream::new(cols, pre as u64, 13);
    let train = stream.take_dataset(pre, "pre-drift");
    let spec = TrainSpec::new(Method::Svrg).epochs(4).seed(13).build().unwrap();
    let frozen = api::train(&spec, &train).unwrap();

    let mut online = OnlineOdm::new(cols, params(), 0.05).unwrap();
    for i in 0..train.rows {
        online.step_dense(train.row(i), train.y[i]);
    }
    let mut tail =
        OnlineOdm::from_weights(online.weights().to_vec(), params(), 0.05, online.seen()).unwrap();
    let mut px = Vec::with_capacity(post * cols);
    let mut py = Vec::with_capacity(post);
    for _ in 0..post {
        let (x, y) = stream.next_example();
        tail.step_dense(&x, y);
        px.extend_from_slice(&x);
        py.push(y);
    }
    let post_ds = Dataset::new("post-drift", px, py, cols);
    let frozen_post = frozen.accuracy(&post_ds).unwrap();
    let online_post = tail.prequential_accuracy();
    assert!(
        online_post >= frozen_post + 0.15,
        "online prequential {online_post:.4} must beat frozen {frozen_post:.4} after drift"
    );
}

/// Snapshot → artifact file on disk → restore resumes the *identical*
/// trajectory: every later prequential decision and the final weights
/// match to the bit (f64 weights serialize shortest-round-trip).
#[test]
fn snapshot_artifact_file_round_trip_restores_bit_exactly() {
    let mut stream = DriftStream::new(7, u64::MAX, 21);
    let mut a = OnlineOdm::new(7, params(), 0.08).unwrap();
    for _ in 0..150 {
        let (x, y) = stream.next_example();
        a.step_dense(&x, y);
    }
    let dir = std::env::temp_dir().join(format!("sodm-online-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("online-snapshot.json");
    a.snapshot().save(&path).unwrap();

    let art = Artifact::load(&path).unwrap();
    assert_eq!(art.meta.method, "online");
    assert_eq!(art.meta.updates, 150);
    let mut b = OnlineOdm::restore(&art, 0.08).unwrap();
    assert_eq!(b.seen(), 150);
    for _ in 0..100 {
        let (x, y) = stream.next_example();
        let da = a.step_dense(&x, y);
        let db = b.step_dense(&x, y);
        assert_eq!(da.to_bits(), db.to_bits(), "prequential decisions diverged after restore");
    }
    let wa: Vec<u64> = a.weights().iter().map(|v| v.to_bits()).collect();
    let wb: Vec<u64> = b.weights().iter().map(|v| v.to_bits()).collect();
    assert_eq!(wa, wb, "weight trajectories diverged after file round trip");
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir(&dir);
}

/// Snapshot isolation through the serve runtime: the compiled plan behind
/// a [`serve_online`] handle is immutable, so one probe must score
/// bit-identically across the whole run while updater threads hammer the
/// shared learner — and the update counter must come out exact.
#[test]
fn concurrent_updates_never_tear_served_scores() {
    let slot = Arc::new(OnlineSlot::new(OnlineOdm::new(6, params(), 0.05).unwrap()));
    // Warm the learner so the served snapshot carries trained weights.
    let mut warm = DriftStream::new(6, u64::MAX, 31);
    for _ in 0..100 {
        let (x, y) = warm.next_example();
        slot.update_dense(&x, y);
    }
    let cfg = ServeConfig { workers: 2, shards: 2, ..ServeConfig::default() };
    let handle = serve_online(Arc::clone(&slot), cfg).unwrap();
    let probe = [0.25f32; 6];
    let want = handle.score(&probe).unwrap();
    assert!(want.is_finite());

    std::thread::scope(|s| {
        for t in 0..3u64 {
            let slot = Arc::clone(&slot);
            s.spawn(move || {
                let mut stream = DriftStream::new(6, u64::MAX, 60 + t);
                for _ in 0..300 {
                    let (x, y) = stream.next_example();
                    slot.update_dense(&x, y);
                }
            });
        }
        for i in 0..200 {
            let got = handle.score(&probe).unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "score {i} torn by a live update");
        }
    });
    assert_eq!(slot.updates(), 100 + 3 * 300, "lost or duplicated updates");

    // A fresh snapshot handle serves the post-update weights; feedback
    // through the *handle* steps the same shared learner.
    let fresh = serve_online(Arc::clone(&slot), ServeConfig::default()).unwrap();
    assert!(fresh.score(&probe).unwrap().is_finite());
    let (x, y) = warm.next_example();
    let seen = fresh.update(&x, y).unwrap();
    assert_eq!(seen, 100 + 3 * 300 + 1);
    handle.stop();
    fresh.stop();
}
