//! Process-level integration tests for the distributed DSVRG runtime
//! ([`sodm::dist`]): real `sodm worker` subprocesses serving out-of-core
//! shards over loopback TCP must reproduce the in-process simulator's
//! trajectory to 1e-9, and a coordinator killed at a checkpoint must
//! resume onto the bit-exact final model. The in-process protocol
//! mechanics (frame handling, version negotiation, byte accounting) are
//! unit-tested inside `sodm::dist`; these tests exercise the real
//! process boundary via `CARGO_BIN_EXE_sodm`.
//!
//! Every test skips (with an eprintln) where loopback sockets are
//! unavailable — sandboxed CI runners without network namespaces.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::Command;

use sodm::api::{self, Artifact, DistSpec, Method, TrainSpec};
use sodm::data::shardfile::write_shards;
use sodm::data::synth::SynthSpec;
use sodm::data::{Dataset, Rows};
use sodm::dist::{self, DistOptions};
use sodm::odm::{OdmModel, OdmParams};
use sodm::svrg::SvrgConfig;

/// Committed 40-row dense fixture (see the acceptance criteria: the
/// equivalence runs hold on committed data, not only on generated draws).
const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures/dist_train.libsvm");

fn loopback_available() -> bool {
    TcpListener::bind("127.0.0.1:0").is_ok()
}

fn exe() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_sodm"))
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sodm_dist_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fixture(rows: usize, seed: u64) -> Dataset {
    let mut s = SynthSpec::named("svmguide1", 0.02, seed);
    s.rows = rows;
    s.generate()
}

fn linear_w(model: &OdmModel) -> &[f64] {
    let OdmModel::Linear { w } = model else { panic!("dsvrg models are linear") };
    w
}

/// The unbuilt spec both sides of an equivalence run share.
fn spec_for(k: usize, seed: u64) -> TrainSpec {
    TrainSpec::new(Method::Dsvrg).workers(1).epochs(3).partitions(k).stratums(8).seed(seed)
}

#[test]
fn worker_processes_match_the_in_process_run_with_2_and_4_workers() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let seed = 0xA11CE;
    let ds = fixture(48, 11);
    for k in [2usize, 4] {
        let dir = temp_dir(&format!("match{k}"));
        let manifest = write_shards(Rows::Dense(&ds), k, 8, seed, &dir, 1).unwrap();
        assert_eq!(manifest.shards, k);

        let sim_spec = spec_for(k, seed).build().unwrap();
        let sim = api::train_run(&sim_spec, &ds, None).unwrap();
        let dist_spec = spec_for(k, seed).distributed(DistSpec::new(&dir, exe())).build().unwrap();
        let out = api::train_distributed(&dist_spec).unwrap();

        let sw = linear_w(sim.artifact.as_binary().unwrap());
        let dw = linear_w(out.run.artifact.as_binary().unwrap());
        assert_eq!(sw.len(), dw.len());
        let gap = sw.iter().zip(dw).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(gap <= 1e-9, "{k} worker processes: max |w gap| = {gap:e}");

        // The whole checkpoint trajectory agrees, not just the endpoint.
        assert_eq!(sim.snapshots.len(), out.run.snapshots.len());
        for (a, b) in sim.snapshots.iter().zip(&out.run.snapshots) {
            assert!(
                (a.objective - b.objective).abs() <= 1e-9,
                "objective gap at a checkpoint: {} vs {}",
                a.objective,
                b.objective
            );
        }

        assert_eq!(out.stats.workers, k);
        assert_eq!(out.stats.bytes_per_epoch.len(), 3, "one bytes figure per epoch");
        assert!(out.stats.bytes_per_epoch.iter().all(|&b| b > 0));
        assert!(!out.interrupted);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn out_of_core_worker_processes_match_the_fully_resident_ones() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let seed = 0xC09E;
    let ds = fixture(48, 17);
    let dir = temp_dir("chunked");
    write_shards(Rows::Dense(&ds), 2, 8, seed, &dir, 1).unwrap();

    let mut resident = DistSpec::new(&dir, exe());
    resident.chunk_rows = 0;
    let mut chunked = DistSpec::new(&dir, exe());
    chunked.chunk_rows = 5; // workers keep 5 rows resident at a time

    let a = api::train_distributed(&spec_for(2, seed).distributed(resident).build().unwrap())
        .unwrap();
    let b = api::train_distributed(&spec_for(2, seed).distributed(chunked).build().unwrap())
        .unwrap();
    let aw = linear_w(a.run.artifact.as_binary().unwrap());
    let bw = linear_w(b.run.artifact.as_binary().unwrap());
    assert_eq!(aw.len(), bw.len());
    for (x, y) in aw.iter().zip(bw) {
        assert_eq!(x.to_bits(), y.to_bits(), "chunked reader must not change the math");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_coordinator_resumes_bit_exact_from_its_checkpoint() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let seed = 0xD15C1;
    let ds = fixture(48, 13);
    let dir = temp_dir("resume");
    let ckpts = dir.join("ckpts");
    let manifest = write_shards(Rows::Dense(&ds), 2, 8, seed, &dir, 1).unwrap();
    let cfg = SvrgConfig {
        epochs: 3,
        partitions: manifest.shards,
        stratums: 8,
        seed,
        ..SvrgConfig::default()
    };
    let params = OdmParams::default();
    let base = DistOptions { grad_workers: 1, ..DistOptions::default() };

    let full = dist::train_from_dir(exe(), &dir, &params, &cfg, &base).unwrap();
    assert!(!full.interrupted);

    // Kill after global stage 3 (mid-epoch 2 of 3), with a 2-stage
    // checkpoint cadence; the stop itself also checkpoints.
    let kill = DistOptions {
        ckpt_dir: Some(ckpts.clone()),
        ckpt_every_stages: 2,
        stop_after_stages: Some(3),
        ..base.clone()
    };
    let killed = dist::train_from_dir(exe(), &dir, &params, &cfg, &kill).unwrap();
    assert!(killed.interrupted);
    let ckpt = killed.last_checkpoint.expect("interrupted run writes a checkpoint");
    assert!(ckpt.ends_with("ckpt_000003.json"), "{}", ckpt.display());

    // Fresh worker processes, resumed coordinator: bit-exact final model.
    let resumed = dist::resume_from_dir(exe(), &dir, &ckpt, &params, &cfg, &base).unwrap();
    assert!(!resumed.interrupted);
    let fw = linear_w(&full.model);
    let rw = linear_w(&resumed.model);
    assert_eq!(fw.len(), rw.len());
    for (a, b) in fw.iter().zip(rw) {
        assert_eq!(a.to_bits(), b.to_bits(), "resume must be bit-exact");
    }

    // The `latest.json` alias resolves to the same cursor.
    let alias = dist::latest_checkpoint(&ckpts);
    let via_alias = dist::resume_from_dir(exe(), &dir, &alias, &params, &cfg, &base).unwrap();
    let aw = linear_w(&via_alias.model);
    for (a, b) in fw.iter().zip(aw) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shard_and_distributed_train_work_through_the_cli() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let dir = temp_dir("cli");
    let shard_dir = dir.join("shards");
    let model = dir.join("model.json");

    let out = Command::new(exe())
        .args(["shard", "--data", FIXTURE, "--seed", "7", "--shards", "2", "--out-dir"])
        .arg(&shard_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "shard failed: {}", String::from_utf8_lossy(&out.stderr));
    assert!(shard_dir.join("manifest.json").is_file());
    assert!(shard_dir.join("shard_0000.sodm").is_file());

    let out = Command::new(exe())
        .args(["train", "--data", FIXTURE, "--distributed", "2", "--seed", "7", "--shard-dir"])
        .arg(&shard_dir)
        .arg("--model-out")
        .arg(&model)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train --distributed failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bytes_per_epoch"), "must report wire traffic: {stdout}");

    let artifact = Artifact::load(&model).unwrap();
    assert_eq!(artifact.meta.method, "dsvrg-dist");
    assert!(artifact.as_binary().is_some());

    // A mismatched seed against an existing shard set is a typed refusal,
    // not silent retraining on differently-partitioned data.
    let out = Command::new(exe())
        .args(["train", "--data", FIXTURE, "--distributed", "2", "--seed", "8", "--shard-dir"])
        .arg(&shard_dir)
        .output()
        .unwrap();
    assert!(!out.status.success(), "seed mismatch must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("seed"), "error must point at the seed: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
