//! LIBSVM parser edge-case fixtures (ISSUE 4 satellite): trailing
//! whitespace, CRLF endings, comment/blank lines, out-of-order and
//! duplicate feature indices, explicit zeros, empty rows, missing trailing
//! newlines, and non-finite label rejection — pinned for the CSR reader,
//! the densifying reader, and the raw multiclass reader.

use sodm::data::libsvm::{read_libsvm, read_libsvm_sparse, read_libsvm_sparse_raw};
use sodm::util::temp_dir;

struct Cleanup(std::path::PathBuf);
impl Drop for Cleanup {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

fn write_fixture(name: &str, contents: &str) -> (Cleanup, std::path::PathBuf) {
    let dir = Cleanup(temp_dir("libsvm-edge"));
    let p = dir.0.join(name);
    std::fs::write(&p, contents).unwrap();
    (dir, p)
}

#[test]
fn trailing_whitespace_and_crlf_lines_parse() {
    let (_d, p) = write_fixture("ws.txt", "+1 1:0.5 2:1.0   \r\n-1 2:2.0\t\n+1 1:1.5 \n");
    let s = read_libsvm_sparse(&p, 0).unwrap();
    assert_eq!(s.rows, 3);
    assert_eq!(s.indptr, vec![0, 2, 3, 4]);
    assert_eq!(s.indices, vec![0, 1, 1, 0]);
    assert_eq!(s.values, vec![0.5, 1.0, 2.0, 1.5]);
    assert_eq!(s.y, vec![1.0, -1.0, 1.0]);
}

#[test]
fn comment_and_blank_lines_are_skipped_anywhere() {
    let text = "# header\n\n+1 1:1.0\n   \n  # indented comment\n-1 2:1.0\n#tail";
    let (_d, p) = write_fixture("c.txt", text);
    let s = read_libsvm_sparse(&p, 0).unwrap();
    assert_eq!(s.rows, 2);
    assert_eq!(s.y, vec![1.0, -1.0]);
    assert_eq!(s.nnz(), 2);
}

#[test]
fn out_of_order_duplicate_and_zero_features_normalize() {
    let (_d, p) = write_fixture("o.txt", "+1 5:5.0 1:1.0 5:0 3:3.0\n-1 2:0 2:2.0\n");
    let s = read_libsvm_sparse(&p, 0).unwrap();
    // row 0: sorted; duplicate column 5 resolved by its last occurrence
    // (an explicit 0, so the entry is dropped entirely)
    assert_eq!(s.indptr, vec![0, 2, 3]);
    assert_eq!(s.indices, vec![0, 2, 1]);
    assert_eq!(s.values, vec![1.0, 3.0, 2.0]);
    // the dense reader agrees with scatter semantics
    let d = read_libsvm(&p, 0).unwrap();
    assert_eq!(d.row(0), &[1.0, 0.0, 3.0, 0.0, 0.0]);
    assert_eq!(d.row(1), &[0.0, 2.0, 0.0, 0.0, 0.0]);
}

#[test]
fn empty_rows_keep_their_labels() {
    // label-only lines are instances with zero stored features
    let (_d, p) = write_fixture("e.txt", "+1\n-1 1:1.0\n+1\n");
    let s = read_libsvm_sparse(&p, 0).unwrap();
    assert_eq!(s.rows, 3);
    assert_eq!(s.indptr, vec![0, 0, 1, 1]);
    assert_eq!(s.y, vec![1.0, -1.0, 1.0]);
    let d = read_libsvm(&p, 0).unwrap();
    assert_eq!(d.row(0), &[0.0]);
    assert_eq!(d.row(2), &[0.0]);
}

#[test]
fn missing_trailing_newline_parses_last_row() {
    let (_d, p) = write_fixture("n.txt", "+1 1:1.0\n-1 2:2.0");
    let s = read_libsvm_sparse(&p, 0).unwrap();
    assert_eq!(s.rows, 2);
    assert_eq!(s.y, vec![1.0, -1.0]);
    assert_eq!(s.cols, 2);
}

#[test]
fn non_finite_labels_are_rejected() {
    for bad in ["nan 1:1.0\n", "inf 1:1.0\n", "-inf 1:1.0\n", "NaN 1:1.0\n"] {
        let (_d, p) = write_fixture("bad.txt", bad);
        let err = read_libsvm_sparse(&p, 0);
        assert!(err.is_err(), "{bad:?} must be rejected, not silently binarized");
    }
}

#[test]
fn malformed_pairs_report_the_line() {
    let (_d, p) = write_fixture("m.txt", "+1 1:1.0\n-1 oops\n");
    let err = read_libsvm_sparse(&p, 0).unwrap_err();
    assert!(format!("{err:#}").contains("line 2"), "error should name the offending line");
}

#[test]
fn raw_reader_preserves_multiclass_labels_with_placeholder_binary_y() {
    let (_d, p) = write_fixture("raw.txt", "3 1:1.0\n0.5 2:1.0\n-2 1:2.0\n");
    let (ds, raw) = read_libsvm_sparse_raw(&p, 0).unwrap();
    assert_eq!(raw, vec![3.0, 0.5, -2.0]);
    assert!(ds.y.iter().all(|y| *y == 1.0), "raw reader carries a +1 placeholder in y");
    assert_eq!(ds.rows, 3);
    // the binarizing reader maps the same file by the ±1 convention
    let mapped = read_libsvm_sparse(&p, 0).unwrap();
    assert_eq!(mapped.y, vec![1.0, 1.0, -1.0]);
}
