//! Quantized scoring-plan coverage (ISSUE 8 acceptance fixtures): an f32
//! coefficient-storage plan must track the f64 plan within 1e-4 relative on
//! dense, CSR, and feature-mapped fixtures; multiclass argmax must agree
//! with the f64 plan on >= 99.9% of a fixture set; and the precision knob
//! must survive the artifact JSON round trip and flow into serving.

use sodm::api::{self, Method, TrainSpec};
use sodm::data::sparse::SparseSynthSpec;
use sodm::data::synth::SynthSpec;
use sodm::data::RowRef;
use sodm::infer::{PlanPrecision, ScoringPlan};
use sodm::kernel::KernelKind;
use sodm::multiclass::{train_ovr, MulticlassSynthSpec, OvrConfig};
use sodm::odm::{train_exact_odm, OdmModel, OdmParams};
use sodm::qp::SolveBudget;
use sodm::serve::{serve, Backend, ServeConfig};
use sodm::util::json::Json;

/// The quantization error bound the plans are pinned to: storing an f64
/// coefficient as f32 perturbs it by <= eps_f32/2 relative, and the f64
/// accumulation adds nothing on top, so decisions drift by well under 1e-4
/// relative to the f64 plan.
fn quant_close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-4 * (1.0 + b.abs())
}

fn dense_fixture() -> (OdmModel, sodm::data::Dataset) {
    let mut spec = SynthSpec::named("svmguide1", 0.02, 21);
    spec.rows = 300;
    let ds = spec.generate();
    let model = train_exact_odm(
        &ds,
        &KernelKind::Rbf { gamma: 1.5 },
        &OdmParams::default(),
        &SolveBudget { max_sweeps: 60, ..SolveBudget::default() },
    );
    (model, ds)
}

#[test]
fn quantized_dense_plan_tracks_f64_within_1e4() {
    let (model, ds) = dense_fixture();
    let plan = ScoringPlan::compile_with(&model, PlanPrecision::F64);
    let qplan = ScoringPlan::compile_with(&model, PlanPrecision::F32);
    assert_eq!(plan.precision(), PlanPrecision::F64);
    assert_eq!(qplan.precision(), PlanPrecision::F32);
    assert_eq!(plan.support_size(), qplan.support_size());
    let refs: Vec<RowRef> = (0..ds.rows).map(|i| RowRef::Dense(ds.row(i))).collect();
    let (mut full, mut quant) = (vec![0.0; refs.len()], vec![0.0; refs.len()]);
    plan.score_block(&refs, &mut full);
    qplan.score_block(&refs, &mut quant);
    for (i, (q, f)) in quant.iter().zip(&full).enumerate() {
        assert!(quant_close(*q, *f), "row {i}: quantized {q} vs f64 {f}");
    }
}

#[test]
fn quantized_csr_plan_tracks_f64_within_1e4() {
    let sp = SparseSynthSpec::new(250, 1500, 0.02, 23).generate();
    let model = train_exact_odm(
        &sp,
        &KernelKind::Rbf { gamma: 0.4 },
        &OdmParams::default(),
        &SolveBudget { max_sweeps: 30, ..SolveBudget::default() },
    );
    assert!(matches!(model, OdmModel::SparseKernel { .. }));
    let plan = ScoringPlan::compile_with(&model, PlanPrecision::F64);
    let qplan = ScoringPlan::compile_with(&model, PlanPrecision::F32);
    assert_eq!(qplan.precision(), PlanPrecision::F32);
    let refs: Vec<RowRef> = (0..sp.rows).map(|i| sp.row_ref(i)).collect();
    let (mut full, mut quant) = (vec![0.0; refs.len()], vec![0.0; refs.len()]);
    plan.score_block(&refs, &mut full);
    qplan.score_block(&refs, &mut quant);
    for (i, (q, f)) in quant.iter().zip(&full).enumerate() {
        assert!(quant_close(*q, *f), "row {i}: quantized {q} vs f64 {f}");
    }
}

#[test]
fn quantized_feature_mapped_plan_tracks_f64_within_1e4() {
    let mut dspec = SynthSpec::named("svmguide1", 0.02, 27);
    dspec.rows = 250;
    let ds = dspec.generate();
    let spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma: 1.0 })
        .rff(64)
        .build()
        .unwrap();
    let artifact = api::train(&spec, &ds).unwrap();
    let model = artifact.as_binary().unwrap();
    assert!(matches!(model, OdmModel::FeatureMapped { .. }));
    let plan = ScoringPlan::compile_with(model, PlanPrecision::F64);
    let qplan = ScoringPlan::compile_with(model, PlanPrecision::F32);
    assert_eq!(qplan.precision(), PlanPrecision::F32);
    let refs: Vec<RowRef> = (0..ds.rows).map(|i| RowRef::Dense(ds.row(i))).collect();
    let (mut full, mut quant) = (vec![0.0; refs.len()], vec![0.0; refs.len()]);
    plan.score_block(&refs, &mut full);
    qplan.score_block(&refs, &mut quant);
    for (i, (q, f)) in quant.iter().zip(&full).enumerate() {
        assert!(quant_close(*q, *f), "row {i}: quantized {q} vs f64 {f}");
    }
}

#[test]
fn quantized_multiclass_argmax_agrees_above_999_per_mille() {
    let mc = MulticlassSynthSpec::new(4, 2000, 8, 29).generate();
    let kernel = KernelKind::Rbf { gamma: 1.0 / 16.0 };
    let budget = SolveBudget { max_sweeps: 30, ..SolveBudget::default() };
    let cfg = OvrConfig { budget, ..OvrConfig::default() };
    let run = train_ovr(&mc, &kernel, &OdmParams::default(), &cfg);
    let plan = run.model.compile_with(PlanPrecision::F64);
    let qplan = run.model.compile_with(PlanPrecision::F32);
    let full = plan.predict_rows(mc.as_rows(), 2);
    let quant = qplan.predict_rows(mc.as_rows(), 2);
    let agree = full.iter().zip(&quant).filter(|(a, b)| a == b).count();
    let rate = agree as f64 / full.len() as f64;
    assert!(rate >= 0.999, "argmax agreement {rate:.4} below the 99.9% gate");
    // Per-class margins stay inside the same quantization bound as the
    // binary plans.
    let fs = plan.score_rows(mc.as_rows(), 2);
    let qs = qplan.score_rows(mc.as_rows(), 2);
    for (i, (q, f)) in qs.iter().zip(&fs).enumerate() {
        assert!(quant_close(*q, *f), "margin {i}: quantized {q} vs f64 {f}");
    }
}

#[test]
fn plan_precision_survives_artifact_round_trip() {
    let mut dspec = SynthSpec::named("svmguide1", 0.02, 31);
    dspec.rows = 200;
    let ds = dspec.generate();
    let spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma: 1.2 })
        .plan_precision(PlanPrecision::F32)
        .build()
        .unwrap();
    let artifact = api::train(&spec, &ds).unwrap();
    assert_eq!(artifact.meta.plan_precision, Some(PlanPrecision::F32));
    let text = artifact.to_json().to_string();
    assert!(text.contains("plan_precision"), "knob must serialize: {text}");
    let back = api::Artifact::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.meta.plan_precision, Some(PlanPrecision::F32));
    // compile_plan honors the recorded knob; compile_plan_with overrides it.
    let plan = back.compile_plan();
    assert_eq!(plan.as_binary().unwrap().precision(), PlanPrecision::F32);
    let forced = back.compile_plan_with(PlanPrecision::F64);
    assert_eq!(forced.as_binary().unwrap().precision(), PlanPrecision::F64);
    // The quantized plan still tracks the f64 plan on the training rows.
    let refs: Vec<RowRef> = (0..ds.rows).map(|i| RowRef::Dense(ds.row(i))).collect();
    let (mut full, mut quant) = (vec![0.0; refs.len()], vec![0.0; refs.len()]);
    forced.as_binary().unwrap().score_block(&refs, &mut full);
    plan.as_binary().unwrap().score_block(&refs, &mut quant);
    for (i, (q, f)) in quant.iter().zip(&full).enumerate() {
        assert!(quant_close(*q, *f), "row {i}: quantized {q} vs f64 {f}");
    }
}

#[test]
fn default_precision_artifacts_keep_historical_json() {
    let mut dspec = SynthSpec::named("svmguide1", 0.02, 33);
    dspec.rows = 150;
    let ds = dspec.generate();
    let spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma: 1.0 })
        .build()
        .unwrap();
    let artifact = api::train(&spec, &ds).unwrap();
    assert_eq!(artifact.meta.plan_precision, None);
    // Only non-default knobs serialize — an f64 artifact's envelope carries
    // no plan_precision key, byte-compatible with pre-quantization readers.
    assert!(!artifact.to_json().to_string().contains("plan_precision"));
}

#[test]
fn serve_with_forced_f32_precision_tracks_f64_decisions() {
    let (model, ds) = dense_fixture();
    let plan = ScoringPlan::compile_with(&model, PlanPrecision::F64);
    let cfg = ServeConfig {
        workers: 2,
        shards: 2,
        precision: Some(PlanPrecision::F32),
        ..ServeConfig::default()
    };
    let h = serve(model.clone(), Backend::Native, cfg).unwrap();
    for i in (0..ds.rows).step_by(7) {
        let got = h.score(ds.row(i)).unwrap();
        let want = plan.score_rr(RowRef::Dense(ds.row(i)));
        assert!(quant_close(got, want), "row {i}: served {got} vs f64 plan {want}");
    }
    h.stop();
}
