//! Numerical verification of the paper's theory on small random instances:
//!
//! * Theorem 1 (Eqns. 5-6): the block-diagonal dual optimum is within
//!   `U²(Q + M(M-m)c)` of the global optimum in objective, and within
//!   `U²(Q + M(M-m)c)/(Mcυ)` in squared distance.
//! * Theorem 2's premise: the stratified partitioner's per-partition
//!   objective gap shrinks as the landmark principal angle grows.

use sodm::data::{all_indices, synth::SynthSpec, DataView, Dataset};
use sodm::kernel::{signed_row, KernelKind};
use sodm::odm::OdmParams;
use sodm::partition::{make_partitions, PartitionStrategy};
use sodm::qp::{odm_dual_objective, solve_odm_dual, SolveBudget};

fn fixture(rows: usize, seed: u64) -> Dataset {
    let mut s = SynthSpec::named("svmguide1", 0.01, seed);
    s.rows = rows;
    s.generate()
}

/// Solve global + per-partition duals; return
/// (global objective, d(ζ̃*, β̃*), ‖α̃*-α*‖², U, Q_offblock, m).
fn theorem1_quantities(
    ds: &Dataset,
    kernel: &KernelKind,
    params: &OdmParams,
    k: usize,
    seed: u64,
) -> (f64, f64, f64, f64, f64, usize) {
    let idx = all_indices(ds);
    let view = DataView::new(ds, &idx);
    let budget = SolveBudget { eps: 1e-6, max_sweeps: 3000, ..Default::default() };
    let global = solve_odm_dual(&view, kernel, params, None, &budget);

    let parts = make_partitions(&view, kernel, k, PartitionStrategy::Random, seed, 1);
    let mut zeta = Vec::new();
    let mut beta = Vec::new();
    let mut concat_idx = Vec::new();
    for p in &parts {
        let pv = DataView::new(ds, p);
        let sol = solve_odm_dual(&pv, kernel, params, None, &budget);
        zeta.extend(sol.zeta);
        beta.extend(sol.beta);
        concat_idx.extend_from_slice(p);
    }
    // Evaluate the concatenated block-diagonal solution under the TRUE dual.
    let cview = DataView::new(ds, &concat_idx);
    let d_tilde = odm_dual_objective(&cview, kernel, params, &zeta, &beta);

    // ‖α̃* − α*‖²: re-solve global in the SAME row order as cview.
    let global_c = solve_odm_dual(&cview, kernel, params, None, &budget);
    let mut dist2 = 0.0;
    for i in 0..zeta.len() {
        let dz = zeta[i] - global_c.zeta[i];
        let db = beta[i] - global_c.beta[i];
        dist2 += dz * dz + db * db;
    }
    let u = zeta
        .iter()
        .chain(beta.iter())
        .chain(global_c.zeta.iter())
        .chain(global_c.beta.iter())
        .fold(0.0f64, |acc, v| acc.max(v.abs()));

    // Q = sum of |Q_ij| over cross-partition pairs (in cview order, the
    // blocks are contiguous).
    let m = cview.len();
    let mut part_of = vec![0usize; m];
    {
        let mut ofs = 0;
        for (pi, p) in parts.iter().enumerate() {
            for j in 0..p.len() {
                part_of[ofs + j] = pi;
            }
            ofs += p.len();
        }
    }
    let mut q_off = 0.0f64;
    let mut row = vec![0.0f32; m];
    for i in 0..m {
        signed_row(&cview, kernel, i, &mut row);
        for j in 0..m {
            if part_of[i] != part_of[j] {
                q_off += row[j].abs() as f64;
            }
        }
    }
    (global.stats.objective, d_tilde, dist2, u, q_off, parts[0].len())
}

#[test]
fn theorem1_objective_gap_within_bound() {
    for seed in [1u64, 2, 3] {
        let ds = fixture(48, seed);
        let params = OdmParams { lambda: 8.0, theta: 0.3, upsilon: 0.5 };
        let kernel = KernelKind::Rbf { gamma: 1.0 };
        let (d_star, d_tilde, _dist2, u, q_off, m_part) =
            theorem1_quantities(&ds, &kernel, &params, 4, seed);
        let gap = d_tilde - d_star;
        // LHS of Eqn. (5): gap >= 0 (optimality of the global solution)
        assert!(gap >= -1e-6, "seed {seed}: negative gap {gap}");
        // RHS of Eqn. (5)
        let m_total = ds.rows as f64;
        let c = params.c();
        let bound = u * u * (q_off + m_total * (m_total - m_part as f64) * c);
        assert!(
            gap <= bound + 1e-6,
            "seed {seed}: gap {gap} exceeds Theorem-1 bound {bound}"
        );
    }
}

#[test]
fn theorem1_distance_within_bound() {
    for seed in [5u64, 8] {
        let ds = fixture(40, seed);
        let params = OdmParams { lambda: 4.0, theta: 0.2, upsilon: 0.8 };
        let kernel = KernelKind::Rbf { gamma: 0.7 };
        let (_d_star, d_tilde, dist2, u, q_off, m_part) =
            theorem1_quantities(&ds, &kernel, &params, 4, seed);
        let m_total = ds.rows as f64;
        let c = params.c();
        let bound = u * u * (q_off + m_total * (m_total - m_part as f64) * c)
            / (m_total * c * params.upsilon as f64);
        assert!(
            dist2 <= bound + 1e-6,
            "seed {seed}: dist² {dist2} exceeds Eqn-6 bound {bound} (d_tilde {d_tilde})"
        );
    }
}

#[test]
fn gap_shrinks_as_partitions_merge() {
    // Theorem 1's convergence story: larger m (fewer partitions) -> smaller
    // gap between block-diagonal and global optimum.
    let ds = fixture(64, 13);
    let params = OdmParams { lambda: 8.0, theta: 0.3, upsilon: 0.5 };
    let kernel = KernelKind::Rbf { gamma: 1.0 };
    let (d_star, d_tilde_8, ..) = theorem1_quantities(&ds, &kernel, &params, 8, 13);
    let (_, d_tilde_2, ..) = theorem1_quantities(&ds, &kernel, &params, 2, 13);
    let gap8 = d_tilde_8 - d_star;
    let gap2 = d_tilde_2 - d_star;
    assert!(
        gap2 <= gap8 + 1e-6,
        "gap with 2 partitions ({gap2}) should be <= gap with 8 ({gap8})"
    );
}

#[test]
fn stratified_gap_not_worse_than_random() {
    // Theorem 2's motivation: distribution-preserving partitions give local
    // solutions whose concatenation sits closer to the global optimum.
    // Averaged over seeds to damp sampling noise.
    let params = OdmParams { lambda: 8.0, theta: 0.3, upsilon: 0.5 };
    let kernel = KernelKind::Rbf { gamma: 1.5 };
    let budget = SolveBudget { eps: 1e-6, max_sweeps: 2000, ..Default::default() };
    let mut total_strat = 0.0;
    let mut total_rand = 0.0;
    for seed in 1..=5u64 {
        let ds = fixture(96, seed);
        let idx = all_indices(&ds);
        let view = DataView::new(&ds, &idx);
        let global = solve_odm_dual(&view, &kernel, &params, None, &budget);
        for (is_strat, strategy) in [
            (true, PartitionStrategy::StratifiedRkhs { stratums: 8 }),
            (false, PartitionStrategy::Random),
        ] {
            let parts = make_partitions(&view, &kernel, 4, strategy, seed, 1);
            let mut zeta = Vec::new();
            let mut beta = Vec::new();
            let mut cidx = Vec::new();
            for p in &parts {
                let pv = DataView::new(&ds, p);
                let sol = solve_odm_dual(&pv, &kernel, &params, None, &budget);
                zeta.extend(sol.zeta);
                beta.extend(sol.beta);
                cidx.extend_from_slice(p);
            }
            let cview = DataView::new(&ds, &cidx);
            let gap = odm_dual_objective(&cview, &kernel, &params, &zeta, &beta)
                - global.stats.objective;
            if is_strat {
                total_strat += gap;
            } else {
                total_rand += gap;
            }
        }
    }
    // allow slack: both are random processes; stratified should not be
    // dramatically worse on average
    assert!(
        total_strat <= total_rand * 1.5 + 1e-3,
        "stratified total gap {total_strat} vs random {total_rand}"
    );
}
