//! Multiclass OVR acceptance fixtures (ISSUE 4): the one-vs-rest pipeline
//! must reach the accuracy of independently trained binary ODMs on every
//! class-vs-rest split, the model must round-trip through JSON bit-exactly,
//! and `score_multiclass` serving must agree with offline `predict_argmax`
//! at 1e-6 on dense and CSR fixtures.

use sodm::data::libsvm::LoadedDataset;
use sodm::data::Dataset;
use sodm::kernel::KernelKind;
use sodm::multiclass::{
    train_ovr, MulticlassDataset, MulticlassModel, MulticlassSynthSpec, OvrConfig,
};
use sodm::odm::{train_exact_odm, OdmParams};
use sodm::qp::SolveBudget;
use sodm::serve::{serve_multiclass, ServeConfig};

fn fixture(classes: usize, rows: usize, seed: u64) -> MulticlassDataset {
    MulticlassSynthSpec::new(classes, rows, 8, seed).generate()
}

/// Materialize the class-`k`-vs-rest binary dataset (test-only copy; the
/// trainer itself binarizes through zero-copy label-override views).
fn binarized(ds: &MulticlassDataset, k: usize) -> Dataset {
    let LoadedDataset::Dense(d) = &ds.data else { panic!("fixture is dense") };
    Dataset::new(format!("class{k}-vs-rest"), d.x.clone(), ds.binary_labels(k), d.cols)
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() < 1e-6 * (1.0 + b.abs())
}

#[test]
fn ovr_reaches_every_binary_odm_accuracy_and_argmax_reaches_the_best() {
    let ds = fixture(4, 320, 41);
    let (train, test) = ds.split(0.8, 43);
    let kernel = KernelKind::Rbf { gamma: 1.0 / 16.0 };
    let params = OdmParams::default();
    let budget = SolveBudget::default();
    let run = train_ovr(&train, &kernel, &params, &OvrConfig { budget, ..Default::default() });

    let n = test.rows();
    let scores = run.model.scores(test.as_rows(), 2);
    let mut best_binary = 0.0f64;
    for k in 0..4 {
        // An independently trained binary ODM on the same class-vs-rest
        // split, with train_ovr's per-class seed derivation so the solves
        // are comparable coordinate for coordinate.
        let budget_k = SolveBudget { seed: budget.seed ^ ((k as u64) << 3), ..budget };
        let reference = train_exact_odm(&binarized(&train, k), &kernel, &params, &budget_k);
        let ref_acc = reference.accuracy(&binarized(&test, k));
        // The OVR class head as a binary classifier on the same split.
        let yk = test.binary_labels(k);
        let right = (0..n).filter(|&i| (scores[k * n + i] >= 0.0) == (yk[i] > 0.0)).count();
        let ovr_acc = right as f64 / n as f64;
        assert!(
            ovr_acc + 1e-12 >= ref_acc,
            "class {k}: OVR head {ovr_acc} must reach the binary ODM {ref_acc}"
        );
        best_binary = best_binary.max(ref_acc);
    }
    let mc_acc = run.model.accuracy(&test, 2);
    assert!(mc_acc > 0.97, "argmax accuracy {mc_acc}");
    assert!(
        mc_acc + 1e-12 >= best_binary,
        "argmax {mc_acc} must reach the best single binary ODM {best_binary}"
    );
}

#[test]
fn model_save_load_round_trips_bit_exact() {
    let ds = fixture(4, 200, 47);
    let run = train_ovr(
        &ds,
        &KernelKind::Rbf { gamma: 1.0 / 16.0 },
        &OdmParams::default(),
        &OvrConfig::default(),
    );
    let dir = sodm::util::temp_dir("mc-acceptance");
    let path = dir.join("model.json");
    run.model.save(&path).unwrap();
    let back = MulticlassModel::load(&path).unwrap();
    assert_eq!(back.class_labels, run.model.class_labels);
    // decisions are bitwise equal, not merely close
    let a = run.model.scores(ds.as_rows(), 2);
    let b = back.scores(ds.as_rows(), 2);
    assert_eq!(a, b);
    // and the serialized form is a fixed point (save -> load -> save)
    back.save(&path).unwrap();
    let again = MulticlassModel::load(&path).unwrap();
    assert_eq!(back.to_json().to_string(), again.to_json().to_string());
    std::fs::remove_dir_all(dir).ok();
}

/// Serve one fixture and check every reply against the offline compiled
/// plan: argmax must match `predict_argmax` and every per-class margin the
/// plan's scores at 1e-6.
fn check_serve_agreement(model: &MulticlassModel, ds: &MulticlassDataset) {
    let plan = model.compile();
    let rows = ds.as_rows();
    let want_pred = plan.predict_rows(rows, 2);
    let want_scores = plan.score_rows(rows, 2);
    let n = ds.rows();
    let cfg = ServeConfig { workers: 3, shards: 2, ..ServeConfig::default() };
    let h = serve_multiclass(model.clone(), cfg).unwrap();
    for i in 0..n.min(24) {
        let got = match &ds.data {
            LoadedDataset::Dense(d) => h.score_multiclass(d.row(i)).unwrap(),
            LoadedDataset::Sparse(s) => {
                let (lo, hi) = (s.indptr[i], s.indptr[i + 1]);
                h.score_multiclass_sparse(&s.indices[lo..hi], &s.values[lo..hi]).unwrap()
            }
        };
        assert_eq!(got.argmax, want_pred[i], "row {i}: serve argmax vs offline");
        assert_eq!(got.scores.len(), model.n_classes());
        for (c, s) in got.scores.iter().enumerate() {
            let w = want_scores[c * n + i];
            assert!(close(*s, w), "row {i} class {c}: served {s} vs offline {w}");
        }
    }
    h.stop();
}

#[test]
fn serving_agrees_with_offline_argmax_on_dense_fixture() {
    let dense = fixture(3, 180, 53);
    let run = train_ovr(
        &dense,
        &KernelKind::Rbf { gamma: 1.0 / 16.0 },
        &OdmParams::default(),
        &OvrConfig::default(),
    );
    check_serve_agreement(&run.model, &dense);
}

#[test]
fn serving_agrees_with_offline_argmax_on_csr_fixture() {
    let sparse = fixture(3, 180, 59).to_sparse();
    let run = train_ovr(
        &sparse,
        &KernelKind::Rbf { gamma: 1.0 / 16.0 },
        &OdmParams::default(),
        &OvrConfig::default(),
    );
    for m in &run.model.models {
        assert!(
            matches!(m, sodm::odm::OdmModel::SparseKernel { .. }),
            "CSR training keeps CSR support vectors"
        );
    }
    check_serve_agreement(&run.model, &sparse);
}
