//! Loopback integration tests for the network serving stack
//! ([`sodm::net`]): wire round-trips against trained models must match the
//! in-process serving runtime bit-for-bit (well, to 1e-9), malformed
//! frames must draw typed error replies without killing the acceptor, and
//! an artifact hot-swap under live traffic must leave zero hung clients.
//!
//! Every test skips (with an eprintln) where loopback sockets are
//! unavailable — sandboxed CI runners without network namespaces.

use std::net::TcpListener;
use std::sync::Arc;

use sodm::api::{self, Artifact, ArtifactModel, Method, OvrOptions, TrainMeta, TrainSpec};
use sodm::data::sparse::SparseSynthSpec;
use sodm::data::synth::SynthSpec;
use sodm::kernel::KernelKind;
use sodm::multiclass::MulticlassSynthSpec;
use sodm::net::frame::{HEADER_LEN, MAGIC, VERSION};
use sodm::net::{ErrorCode, ModelRegistry, NetClient, NetServer, Outcome, Reply, Request};
use sodm::odm::OdmModel;
use sodm::qp::SolveBudget;
use sodm::serve::ServeConfig;

fn loopback_available() -> bool {
    TcpListener::bind("127.0.0.1:0").is_ok()
}

fn linear_artifact(w: Vec<f64>) -> Artifact {
    let model = ArtifactModel::Binary(OdmModel::Linear { w });
    let meta = TrainMeta::legacy(&model);
    Artifact { model, meta }
}

fn rbf_spec(gamma: f32) -> TrainSpec {
    let budget = SolveBudget { max_sweeps: 20, ..SolveBudget::default() };
    TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma })
        .budget(budget)
        .build()
        .unwrap()
}

fn serve_net(artifact: Artifact) -> (NetServer, NetClient) {
    let cfg = ServeConfig { workers: 2, shards: 2, ..ServeConfig::default() };
    let registry = Arc::new(ModelRegistry::start(artifact, cfg).unwrap());
    let server = NetServer::bind("127.0.0.1:0", registry).unwrap();
    let client = NetClient::connect(server.local_addr()).unwrap();
    (server, client)
}

/// A raw frame with an arbitrary (possibly invalid) kind byte and payload.
fn raw_frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(HEADER_LEN + payload.len());
    f.extend_from_slice(&MAGIC);
    f.push(VERSION);
    f.push(kind);
    f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    f.extend_from_slice(payload);
    f
}

#[test]
fn dense_remote_scores_match_in_process_serving() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let mut sgen = SynthSpec::named("svmguide1", 0.01, 7);
    sgen.rows = 160;
    let ds = sgen.generate();
    let artifact = api::train(&rbf_spec(1.0), &ds).unwrap();
    let reference = artifact.serve(ServeConfig::default()).unwrap();

    let (server, mut client) = serve_net(artifact);
    for i in 0..24 {
        let x = ds.row(i * 5 % ds.rows);
        let want = reference.score(x).unwrap();
        let got = client.score(x).unwrap().value().unwrap();
        assert!((got - want).abs() < 1e-9, "row {i}: remote {got} vs in-process {want}");
    }
    reference.stop();
    server.stop();
}

#[test]
fn sparse_remote_scores_match_in_process_serving() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let sp = SparseSynthSpec::new(160, 500, 0.03, 5).generate();
    let artifact = api::train(&rbf_spec(0.5), &sp).unwrap();
    let reference = artifact.serve(ServeConfig::default()).unwrap();

    let (server, mut client) = serve_net(artifact);
    for i in 0..24 {
        let j = i * 7 % sp.rows;
        let (lo, hi) = (sp.indptr[j], sp.indptr[j + 1]);
        let (idx, val) = (&sp.indices[lo..hi], &sp.values[lo..hi]);
        let want = reference.score_sparse(idx, val).unwrap();
        let got = client.score_sparse(idx, val).unwrap().value().unwrap();
        assert!((got - want).abs() < 1e-9, "row {j}: remote {got} vs in-process {want}");
    }
    reference.stop();
    server.stop();
}

#[test]
fn multiclass_remote_agrees_with_in_process_serving() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let mc = MulticlassSynthSpec::new(3, 150, 8, 11).generate();
    let budget = SolveBudget { max_sweeps: 20, ..SolveBudget::default() };
    let spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma: 0.5 })
        .budget(budget)
        .multiclass(OvrOptions::default())
        .build()
        .unwrap();
    let artifact = api::train(&spec, &mc).unwrap();
    let reference = artifact.serve(ServeConfig::default()).unwrap();

    let (server, mut client) = serve_net(artifact);
    let cols = reference.input_cols();
    for i in 0..12 {
        let x: Vec<f32> = (0..cols).map(|c| ((i * 31 + c * 7) % 13) as f32 / 13.0).collect();
        let want = reference.score_multiclass(&x).unwrap();
        let (argmax, scores) = client.score_multiclass(&x).unwrap().value().unwrap();
        assert_eq!(argmax, want.argmax, "probe {i}");
        assert_eq!(scores.len(), want.scores.len());
        for (a, b) in scores.iter().zip(&want.scores) {
            assert!((a - b).abs() < 1e-9, "probe {i}: {a} vs {b}");
        }
    }
    reference.stop();
    server.stop();
}

#[test]
fn malformed_frames_draw_typed_errors_without_killing_the_acceptor() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let (server, mut client) = serve_net(linear_artifact(vec![2.0, -1.0]));

    // Recoverable: unknown request kind — typed Malformed reply, and the
    // *same* connection keeps serving.
    let reply = client.send_raw(&raw_frame(0x7F, &[])).unwrap();
    match reply {
        Reply::Error { code, .. } => assert_eq!(code as u8, ErrorCode::Malformed as u8),
        other => panic!("expected error reply, got kind 0x{:02x}", other.kind()),
    }
    // Recoverable: a dense-score payload whose declared length lies.
    let mut bad = 3u32.to_le_bytes().to_vec();
    bad.extend_from_slice(&1.0f32.to_le_bytes()); // promises 3 values, ships 1
    let reply = client.send_raw(&raw_frame(0x01, &bad)).unwrap();
    assert!(matches!(reply, Reply::Error { code: ErrorCode::Malformed, .. }));
    let got = client.score(&[1.0, 1.0]).unwrap().value().unwrap();
    assert!((got - 1.0).abs() < 1e-12, "connection must survive recoverable malformations");

    // Desyncing: bad magic — typed reply, then the server closes this
    // connection (frame boundaries are untrustworthy).
    let reply = client.send_raw(b"XXXX\x01\x01\x00\x00\x00\x00").unwrap();
    assert!(matches!(reply, Reply::Error { code: ErrorCode::Malformed, .. }));
    assert!(client.score(&[1.0, 1.0]).is_err(), "desynced connection must be closed");

    // The acceptor survived all of it: a fresh connection scores fine.
    let mut fresh = NetClient::connect(server.local_addr()).unwrap();
    let got = fresh.score(&[3.0, 1.0]).unwrap().value().unwrap();
    assert!((got - 5.0).abs() < 1e-12);
    assert!(server.net_metrics().malformed.load(std::sync::atomic::Ordering::Relaxed) >= 3);
    server.stop();
}

#[test]
fn version_mismatch_draws_a_typed_admin_reply_naming_both_versions() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let (server, mut client) = serve_net(linear_artifact(vec![2.0]));
    // An otherwise-valid frame whose version byte is from the future: the
    // server answers the protocol-negotiation reply — a typed Admin error
    // naming both versions — then closes (frame boundaries can't be
    // trusted across a version gap).
    let mut f = raw_frame(0x01, &[]);
    f[4] = 9;
    let reply = client.send_raw(&f).unwrap();
    match reply {
        Reply::Error { code, msg } => {
            assert_eq!(code as u8, ErrorCode::Admin as u8);
            assert!(msg.contains("v9"), "must name the peer's version: {msg}");
            assert!(msg.contains(&format!("v{VERSION}")), "must name its own version: {msg}");
        }
        other => panic!("expected admin error reply, got kind 0x{:02x}", other.kind()),
    }
    assert!(client.score(&[1.0]).is_err(), "mismatched-version connection must be closed");
    server.stop();
}

#[test]
fn oversized_and_non_finite_requests_are_rejected_typed() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let (server, mut client) = serve_net(linear_artifact(vec![1.0, 1.0]));

    // Validation failures come back as typed Invalid wire errors.
    match client.score(&[f32::NAN, 1.0]).unwrap() {
        Outcome::Rejected { code, .. } => assert!(matches!(code, ErrorCode::Invalid)),
        Outcome::Value(v) => panic!("NaN request must be rejected, got {v}"),
    }
    match client.score(&[1.0]).unwrap() {
        Outcome::Rejected { code, .. } => assert!(matches!(code, ErrorCode::Invalid)),
        Outcome::Value(v) => panic!("shape-mismatched request must be rejected, got {v}"),
    }
    // An absurd declared payload length closes the stream after the reply.
    let mut huge = raw_frame(0x01, &[]);
    let len = huge.len();
    huge[len - 4..].copy_from_slice(&(u32::MAX).to_le_bytes());
    let reply = client.send_raw(&huge).unwrap();
    assert!(matches!(reply, Reply::Error { code: ErrorCode::Malformed, .. }));
    assert!(client.score(&[1.0, 1.0]).is_err());
    server.stop();
}

#[test]
fn hot_swap_under_live_traffic_leaves_no_hung_clients() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let cfg = ServeConfig { workers: 2, shards: 2, ..ServeConfig::default() };
    let registry = Arc::new(ModelRegistry::start(linear_artifact(vec![1.0, 0.0]), cfg).unwrap());
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let addr = server.local_addr();

    let dir = std::env::temp_dir().join("sodm_net_swap_test");
    std::fs::create_dir_all(&dir).unwrap();
    let vnext = dir.join("vnext.json");
    linear_artifact(vec![0.0, 2.0]).save(&vnext).unwrap();

    let clients = 4;
    let per_client = 150;
    let outcomes: Vec<(u64, u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                s.spawn(|| {
                    let mut conn = NetClient::connect(addr).unwrap();
                    let (mut old_gen, mut new_gen, mut rejected) = (0u64, 0u64, 0u64);
                    for _ in 0..per_client {
                        match conn.score(&[1.0, 1.0]).unwrap() {
                            Outcome::Value(v) if (v - 1.0).abs() < 1e-12 => old_gen += 1,
                            Outcome::Value(v) if (v - 2.0).abs() < 1e-12 => new_gen += 1,
                            Outcome::Value(v) => panic!("impossible score {v}"),
                            Outcome::Rejected { .. } => rejected += 1,
                        }
                    }
                    (old_gen, new_gen, rejected)
                })
            })
            .collect();
        // Swap mid-traffic, from a separate admin connection.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let mut admin = NetClient::connect(addr).unwrap();
        let v = admin.admin_swap(vnext.to_str().unwrap()).unwrap();
        assert_eq!(v, 2);
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let (mut old_gen, mut new_gen, mut rejected) = (0u64, 0u64, 0u64);
    for (o, n, r) in outcomes {
        old_gen += o;
        new_gen += n;
        rejected += r;
    }
    // Zero hangs: every single request resolved with a score or a typed
    // rejection. Post-swap requests score on the new generation.
    assert_eq!(old_gen + new_gen + rejected, (clients * per_client) as u64);
    assert!(new_gen > 0, "swap must land mid-traffic (old {old_gen} / new {new_gen})");
    assert_eq!(registry.version(), 2);
    let mut probe = NetClient::connect(addr).unwrap();
    let got = probe.score(&[1.0, 1.0]).unwrap().value().unwrap();
    assert!((got - 2.0).abs() < 1e-12, "fresh connections score on v2");
    server.stop();
    let _ = std::fs::remove_file(&vnext);
}

#[test]
fn admin_fault_frame_kills_a_scorer_and_the_server_recovers() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let cfg = ServeConfig { workers: 1, shards: 1, ..ServeConfig::default() };
    let registry = Arc::new(ModelRegistry::start(linear_artifact(vec![1.0, 0.0]), cfg).unwrap());
    let server = NetServer::bind("127.0.0.1:0", registry).unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();

    assert_eq!(client.admin_fault(1, 0).unwrap(), 1);
    // The poisoned batch resolves with a typed Failed error — not a hang —
    // and the pool keeps serving afterwards.
    match client.score(&[4.0, 0.0]).unwrap() {
        Outcome::Rejected { code, .. } => assert!(matches!(code, ErrorCode::Failed)),
        Outcome::Value(v) => panic!("poisoned batch must fail typed, got {v}"),
    }
    let got = client.score(&[4.0, 0.0]).unwrap().value().unwrap();
    assert!((got - 4.0).abs() < 1e-12, "scorer pool survives the panic");
    let metrics = client.metrics().unwrap();
    let parsed = sodm::util::json::Json::parse(&metrics).unwrap();
    assert_eq!(parsed.req("scorer_panics").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(parsed.req("failed_batches").unwrap().as_f64().unwrap(), 1.0);
    server.stop();
}

#[test]
fn health_frame_reports_version_and_shape() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let (server, mut client) = serve_net(linear_artifact(vec![1.0, 2.0, 3.0]));
    let health = sodm::util::json::Json::parse(&client.health().unwrap()).unwrap();
    assert_eq!(health.req("version").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(health.req("cols").unwrap().as_f64().unwrap(), 3.0);
    assert!(health.req("running").unwrap().as_bool().unwrap());
    assert_eq!(health.req("source").unwrap().as_str().unwrap(), "<initial>");
    server.stop();
}

#[test]
fn wire_protocol_round_trips_every_request_kind() {
    // Pure codec test — no sockets needed, runs everywhere.
    let reqs = vec![
        Request::ScoreDense(vec![1.0, -2.5]),
        Request::ScoreSparse { indices: vec![3, 9], values: vec![0.5, -0.5] },
        Request::MulticlassDense(vec![0.25; 4]),
        Request::MulticlassSparse { indices: vec![0], values: vec![1.0] },
        Request::Health,
        Request::Metrics,
        Request::AdminSwap { path: "/tmp/vnext.json".into() },
        Request::AdminFault { panics: 2, stall_ms: 50 },
    ];
    for req in reqs {
        let bytes = req.to_frame();
        let mut cur = &bytes[..];
        match sodm::net::frame::read_request(&mut cur).unwrap() {
            sodm::net::frame::ReadOutcome::Frame(back) => {
                assert_eq!(back.kind(), req.kind());
                assert_eq!(back.to_frame(), bytes);
            }
            other => panic!("kind 0x{:02x} failed to round-trip: {other:?}", req.kind()),
        }
    }
}

/// Online fault drill: feedback updates and scores race snapshot-driven
/// hot-swaps over real sockets. The contract under test — zero lost or
/// duplicated updates (exactly-once counting across every swap), no typed
/// `Stopped` ever leaking to a healthy client, and the served artifact
/// advancing through the cadence-triggered versions.
#[test]
fn online_updates_survive_snapshot_swaps_without_loss() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    use sodm::odm::OdmParams;
    use sodm::online::{DriftStream, OnlineOdm};

    let params = OdmParams { lambda: 8.0, theta: 0.2, upsilon: 0.5 };
    let learner = OnlineOdm::new(8, params, 0.05).unwrap();
    let cfg = ServeConfig {
        workers: 2,
        shards: 2,
        max_wait: std::time::Duration::from_millis(1),
        ..ServeConfig::default()
    };
    let cadence = 20u64;
    let registry = Arc::new(ModelRegistry::start_online(learner, cfg, cadence).unwrap());
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&registry)).unwrap();
    let addr = server.local_addr();

    let updaters = 3usize;
    let per_updater = 80usize;
    let scores = 120usize;
    std::thread::scope(|s| {
        for t in 0..updaters {
            s.spawn(move || {
                let mut conn = NetClient::connect(addr).unwrap();
                let mut stream = DriftStream::new(8, u64::MAX, 40 + t as u64);
                for _ in 0..per_updater {
                    let (x, y) = stream.next_example();
                    match conn.update(&x, y).unwrap() {
                        Outcome::Value((seen, _version)) => {
                            assert!(seen >= 1, "seen counter must be post-update");
                        }
                        Outcome::Rejected { code, msg } => {
                            panic!("update rejected mid-stream ({code:?}): {msg}")
                        }
                    }
                }
            });
        }
        // A scorer hammers the same server across every swap: values only,
        // or Overloaded shed — never Stopped, never a transport error.
        let mut conn = NetClient::connect(addr).unwrap();
        let probe = [0.5f32; 8];
        for i in 0..scores {
            match conn.score(&probe).unwrap() {
                Outcome::Value(d) => assert!(d.is_finite(), "score {i} not finite"),
                Outcome::Rejected { code, msg } => {
                    assert!(
                        matches!(code, ErrorCode::Overloaded),
                        "score {i} drew non-shed rejection ({code:?}): {msg}"
                    );
                }
            }
        }
    });

    let submitted = (updaters * per_updater) as u64;
    let slot = registry.online_slot().expect("online registry");
    assert_eq!(slot.updates(), submitted, "lost or duplicated updates across swaps");
    // Concurrent CAS claims may merge cadence boundaries (one swap can
    // cover several), but with 240 updates at cadence 20 at least one swap
    // is guaranteed: the first updater to check past a boundary wins the
    // CAS (a failed CAS means another updater's swap succeeded).
    assert!(
        registry.version() >= 2,
        "cadence swaps must advance the artifact: v{} after {submitted} updates",
        registry.version()
    );
    // The snapshot the registry would publish next counts every update too.
    assert_eq!(slot.snapshot().meta.updates, submitted);
    server.stop();
}

#[test]
fn remote_benchmark_quick_drill_resolves_every_request() {
    if !loopback_available() {
        eprintln!("skipping: loopback sockets unavailable");
        return;
    }
    let (json, summary) = sodm::exp::run_remote_serve_benchmark(2, 2, true, 7).unwrap();
    assert!(!json.req("skipped").unwrap().as_bool().unwrap(), "{summary}");
    let submitted = json.req("submitted").unwrap().as_f64().unwrap();
    let resolved = json.req("resolved").unwrap().as_f64().unwrap();
    assert_eq!(resolved, submitted, "zero hung clients: {summary}");
    assert_eq!(json.req("transport_errors").unwrap().as_f64().unwrap(), 0.0, "{summary}");
    assert_eq!(json.req("final_version").unwrap().as_f64().unwrap(), 2.0, "{summary}");
    assert!(json.req("p99_ms").unwrap().as_f64().unwrap() >= 0.0);
}
