//! Bench: regenerate paper Figure 2 (speedup vs cores, task-replay model).
use sodm::exp::figures::figure2;
use sodm::exp::ExpConfig;

fn main() {
    let cfg = ExpConfig { scale: 0.05, out_dir: "results/bench".into(), ..Default::default() };
    let (out, _) = figure2(&cfg, &[1, 2, 4, 8, 16, 32], "ijcnn1").expect("figure2");
    println!("{out}");
}
