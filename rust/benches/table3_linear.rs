//! Bench: regenerate paper Table 3 (linear kernel) at bench scale.
use sodm::exp::tables::table3;
use sodm::exp::ExpConfig;

fn main() {
    let cfg = ExpConfig {
        scale: 0.02,
        datasets: vec!["svmguide1".into(), "a7a".into(), "SUSY".into()],
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = table3(&cfg).expect("table3");
    println!("{out}");
    println!("bench total: {:.2}s", t0.elapsed().as_secs_f64());
}
