//! Hot-path microbenchmarks — the §Perf instrument (EXPERIMENTS.md).
//!
//! Measures the kernels the whole stack stands on: signed Gram row
//! evaluation, DCD sweep throughput (kernel + linear), the SVRG full
//! gradient, landmark selection, batch prediction, and (when artifacts are
//! present) the PJRT Pallas paths. In-crate harness (`util::bench_loop`)
//! reports mean/min over repeated runs.

use sodm::data::{all_indices, synth::SynthSpec, DataView};
use sodm::kernel::{signed_row, KernelKind};
use sodm::odm::OdmParams;
use sodm::partition::landmarks::Nystrom;
use sodm::qp::{solve_odm_dual, SolveBudget};
use sodm::runtime::XlaEngine;
use sodm::svrg::grad_sum_native;
use sodm::util::bench_loop;

fn report(name: &str, unit_count: f64, unit: &str, stats: &sodm::util::TimingStats) {
    println!(
        "{name:<34} mean {:>9.3} ms   min {:>9.3} ms   {:>12.0} {unit}/s",
        stats.mean() * 1e3,
        stats.min() * 1e3,
        unit_count / stats.min()
    );
}

fn main() {
    let mut spec = SynthSpec::named("ijcnn1", 0.02, 5);
    spec.rows = 4000;
    let ds = spec.generate();
    let idx = all_indices(&ds);
    let view = DataView::new(&ds, &idx);
    let rbf = KernelKind::Rbf { gamma: 1.0 };
    let params = OdmParams::default();
    println!(
        "hotpath benches on {} rows x {} features\n",
        ds.rows, ds.cols
    );

    // 1. signed Gram row (the unit the DCD cache stores)
    let mut row = vec![0.0f32; view.len()];
    let stats = bench_loop(2, 10, || {
        signed_row(&view, &rbf, 7, &mut row);
        row[0]
    });
    report("gram row (rbf, 4k cols)", view.len() as f64, "kval", &stats);

    // 2. one DCD sweep, kernel path (fresh solver, 1 sweep)
    let budget1 = SolveBudget { max_sweeps: 1, ..Default::default() };
    let stats = bench_loop(1, 5, || solve_odm_dual(&view, &rbf, &params, None, &budget1));
    report("DCD sweep (rbf kernel path)", 2.0 * view.len() as f64, "coord", &stats);

    // 3. one DCD sweep, linear path
    let stats = bench_loop(1, 5, || {
        solve_odm_dual(&view, &KernelKind::Linear, &params, None, &budget1)
    });
    report("DCD sweep (linear path)", 2.0 * view.len() as f64, "coord", &stats);

    // 3b. DCD v2: shrinking + prefetch vs the no-shrink reference, to
    // convergence on a 1k-row subproblem — prints the telemetry that makes
    // the speedup measurable (sweeps / updates / shrink ratio / hit rate).
    {
        let sub_idx: Vec<usize> = (0..1000.min(ds.rows)).collect();
        let sub = DataView::new(&ds, &sub_idx);
        let base = SolveBudget { eps: 1e-3, max_sweeps: 120, ..Default::default() };
        for (name, budget) in [
            ("no-shrink reference", SolveBudget { shrink: false, ..base }),
            ("shrink (default)", base),
            ("shrink + ordered k=4", SolveBudget { ordered_every: 4, ..base }),
        ] {
            let (sol, secs) =
                sodm::util::time_it(|| solve_odm_dual(&sub, &rbf, &params, None, &budget));
            println!(
                "DCD v2 {:<22} {:>8.1} ms  sweeps {:>4}  updates {:>8}  shrink {:>5.2}  hit-rate {:>5.2}  conv {}",
                name,
                secs * 1e3,
                sol.stats.sweeps,
                sol.stats.updates,
                sol.stats.shrink_ratio,
                sol.stats.cache_hit_rate,
                sol.stats.converged,
            );
        }
        println!();
    }

    // 4. SVRG full gradient (native)
    let w = vec![0.1f64; ds.cols];
    let stats = bench_loop(2, 10, || grad_sum_native(&w, &view, &params, 1));
    report("full gradient (native)", view.len() as f64, "row", &stats);

    // 5. landmark selection (greedy pivoted Cholesky, S=32)
    let stats = bench_loop(1, 5, || Nystrom::select(&view, &rbf, 32, 2048, 3));
    report("landmark select (S=32, pool 2048)", 2048.0 * 32.0, "cand*s", &stats);

    // 6. batch prediction, native
    let model = sodm::odm::train_exact_odm(
        &ds,
        &rbf,
        &params,
        &SolveBudget { max_sweeps: 5, ..Default::default() },
    );
    let stats = bench_loop(1, 5, || model.accuracy(&ds));
    report("batch predict (native kernel)", ds.rows as f64, "row", &stats);

    // 7-8. PJRT artifact paths (skipped without artifacts)
    match XlaEngine::load_default() {
        Some(engine) => {
            let m = engine.geometry.gram_m;
            let x1 = &ds.x[..m * ds.cols];
            let y1 = &ds.y[..m];
            let stats = bench_loop(2, 10, || {
                engine.rbf_gram_block(x1, y1, x1, y1, ds.cols, 1.0).expect("gram")
            });
            report("PJRT gram block (256x256 pallas)", (m * m) as f64, "kval", &stats);

            let stats = bench_loop(2, 10, || {
                engine
                    .odm_grad_sum(&w, &ds.x[..1024 * ds.cols], &ds.y[..1024], ds.cols, &params)
                    .expect("grad")
            });
            report("PJRT odm_grad (1024 pallas)", 1024.0, "row", &stats);
        }
        None => println!("(PJRT benches skipped: run `make artifacts`)"),
    }
}
