//! Hot-path microbenchmarks — the §Perf instrument (EXPERIMENTS.md).
//!
//! Measures the kernels the whole stack stands on: signed Gram row
//! evaluation, DCD sweep throughput (kernel + linear), the SVRG full
//! gradient, landmark selection, batch prediction, the sparse CSR path
//! against its densified twin, and (when artifacts are present) the PJRT
//! Pallas paths. In-crate harness (`util::bench_loop`) reports mean/min
//! over repeated runs.
//!
//! Flags (after `--` in `cargo bench --bench hotpath -- ...`):
//! * `--quick`            — CI budget: smaller fixtures, fewer iterations
//! * `--json <path>`      — write the run as a JSON summary (the CI bench
//!   artifact; seeds the bench trajectory)
//! * `--simd-json <path>` — write the SIMD-core section (scalar baseline vs
//!   the active core vs the quantized f32-storage plan) as its own summary
//!   (`{"name": "simd", "simd_enabled": ..., "benches": [...]}`)

use sodm::data::sparse::SparseSynthSpec;
use sodm::data::{all_indices, identity_indices, synth::SynthSpec, DataView};
use sodm::kernel::{signed_row, KernelKind};
use sodm::odm::{OdmModel, OdmParams};
use sodm::partition::landmarks::Nystrom;
use sodm::qp::{solve_odm_dual, SolveBudget};
use sodm::runtime::XlaEngine;
use sodm::svrg::{grad_sum_native, train_svrg, NativeGrad, SvrgConfig};
use sodm::util::bench_loop;
use sodm::util::json::{jstr, Json};

/// One reported line, kept for the JSON summary.
struct Entry {
    name: String,
    mean_ms: f64,
    min_ms: f64,
    rate: f64,
    unit: String,
}

struct Report {
    entries: Vec<Entry>,
}

impl Report {
    fn push(&mut self, name: &str, unit_count: f64, unit: &str, stats: &sodm::util::TimingStats) {
        let e = Entry {
            name: name.to_string(),
            mean_ms: stats.mean() * 1e3,
            min_ms: stats.min() * 1e3,
            rate: unit_count / stats.min(),
            unit: unit.to_string(),
        };
        println!(
            "{:<34} mean {:>9.3} ms   min {:>9.3} ms   {:>12.0} {}/s",
            e.name, e.mean_ms, e.min_ms, e.rate, e.unit
        );
        self.entries.push(e);
    }

    fn benches_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("name", jstr(e.name.clone())),
                        ("mean_ms", Json::Num(e.mean_ms)),
                        ("min_ms", Json::Num(e.min_ms)),
                        ("rate", Json::Num(e.rate)),
                        ("unit", jstr(e.unit.clone())),
                    ])
                })
                .collect(),
        )
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![("benches", self.benches_json())])
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let simd_json_path = args
        .iter()
        .position(|a| a == "--simd-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut report = Report { entries: Vec::new() };
    let mut simd_report = Report { entries: Vec::new() };
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };

    let mut spec = SynthSpec::named("ijcnn1", 0.02, 5);
    spec.rows = if quick { 1500 } else { 4000 };
    let ds = spec.generate();
    let idx = all_indices(&ds);
    let view = DataView::new(&ds, &idx);
    let rbf = KernelKind::Rbf { gamma: 1.0 };
    let params = OdmParams::default();
    println!(
        "hotpath benches on {} rows x {} features{}\n",
        ds.rows,
        ds.cols,
        if quick { " (quick budget)" } else { "" }
    );

    // 1. signed Gram row (the unit the DCD cache stores)
    let mut row = vec![0.0f32; view.len()];
    let stats = bench_loop(warm, iters, || {
        signed_row(&view, &rbf, 7, &mut row);
        row[0]
    });
    report.push("gram row (rbf, dense)", view.len() as f64, "kval", &stats);

    // 2. one DCD sweep, kernel path (fresh solver, 1 sweep)
    let budget1 = SolveBudget { max_sweeps: 1, ..Default::default() };
    let stats = bench_loop(1, iters.min(5), || {
        solve_odm_dual(&view, &rbf, &params, None, &budget1)
    });
    report.push("DCD sweep (rbf kernel path)", 2.0 * view.len() as f64, "coord", &stats);

    // 3. one DCD sweep, linear path
    let stats = bench_loop(1, iters.min(5), || {
        solve_odm_dual(&view, &KernelKind::Linear, &params, None, &budget1)
    });
    report.push("DCD sweep (linear path)", 2.0 * view.len() as f64, "coord", &stats);

    // 3b. DCD v2: shrinking + prefetch vs the no-shrink reference, to
    // convergence on a 1k-row subproblem — prints the telemetry that makes
    // the speedup measurable (sweeps / updates / shrink ratio / hit rate).
    {
        let sub_idx: Vec<usize> = (0..1000.min(ds.rows)).collect();
        let sub = DataView::new(&ds, &sub_idx);
        let base = SolveBudget { eps: 1e-3, max_sweeps: 120, ..Default::default() };
        for (name, budget) in [
            ("no-shrink reference", SolveBudget { shrink: false, ..base }),
            ("shrink (default)", base),
            ("shrink + ordered k=4", SolveBudget { ordered_every: 4, ..base }),
        ] {
            let (sol, secs) =
                sodm::util::time_it(|| solve_odm_dual(&sub, &rbf, &params, None, &budget));
            println!(
                "DCD v2 {:<22} {:>8.1} ms  sweeps {:>4}  updates {:>8}  shrink {:>5.2}  hit-rate {:>5.2}  conv {}",
                name,
                secs * 1e3,
                sol.stats.sweeps,
                sol.stats.updates,
                sol.stats.shrink_ratio,
                sol.stats.cache_hit_rate,
                sol.stats.converged,
            );
        }
        println!();
    }

    // 4. SVRG full gradient (native)
    let w = vec![0.1f64; ds.cols];
    let stats = bench_loop(warm, iters, || grad_sum_native(&w, &view, &params, 1));
    report.push("full gradient (native)", view.len() as f64, "row", &stats);

    // 5. landmark selection (greedy pivoted Cholesky, S=32)
    let stats = bench_loop(1, iters.min(5), || Nystrom::select(&view, &rbf, 32, 2048, 3));
    report.push("landmark select (S=32, pool 2048)", 2048.0 * 32.0, "cand*s", &stats);

    // 6. batch prediction, native
    let model = sodm::odm::train_exact_odm(
        &ds,
        &rbf,
        &params,
        &SolveBudget { max_sweeps: 5, ..Default::default() },
    );
    let stats = bench_loop(1, iters.min(5), || model.accuracy(&ds));
    report.push("batch predict (native kernel)", ds.rows as f64, "row", &stats);

    // 7. sparse CSR path vs densified twin — the representation win the
    // sparse data path exists for: identical semantics, O(nnz) work.
    {
        let rows = if quick { 800 } else { 2000 };
        let cols = if quick { 2000 } else { 4000 };
        let sp = SparseSynthSpec::new(rows, cols, 0.01, 9).generate();
        let dense = sp.to_dense();
        println!(
            "\nsparse section: {} rows x {} cols, nnz {} (density {:.4})",
            sp.rows,
            sp.cols,
            sp.nnz(),
            sp.density()
        );
        let sp_idx = identity_indices(sp.rows);
        let d_idx = all_indices(&dense);
        let sp_view = DataView::sparse(&sp, &sp_idx);
        let d_view = DataView::new(&dense, &d_idx);
        let gamma = KernelKind::Rbf { gamma: 0.1 };
        let mut out = vec![0.0f32; sp.rows];
        let stats = bench_loop(warm, iters, || {
            signed_row(&sp_view, &gamma, 3, &mut out);
            out[0]
        });
        report.push("gram row (rbf, sparse CSR)", sp.rows as f64, "kval", &stats);
        let stats = bench_loop(warm, iters, || {
            signed_row(&d_view, &gamma, 3, &mut out);
            out[0]
        });
        report.push("gram row (rbf, dense twin)", sp.rows as f64, "kval", &stats);

        let wlin = vec![0.05f64; sp.cols];
        let stats = bench_loop(warm, iters, || grad_sum_native(&wlin, &sp_view, &params, 1));
        report.push("full gradient (sparse CSR)", sp.rows as f64, "row", &stats);
        let stats = bench_loop(warm, iters, || grad_sum_native(&wlin, &d_view, &params, 1));
        report.push("full gradient (dense twin)", sp.rows as f64, "row", &stats);

        // one full SVRG epoch, lazy sparse steps vs eager dense steps
        let cfg = SvrgConfig { epochs: 1, checkpoints_per_epoch: 1, ..Default::default() };
        let grad = NativeGrad { workers: 1 };
        let stats = bench_loop(1, iters.min(3), || {
            let run = train_svrg(&sp, &params, &cfg, &grad);
            let OdmModel::Linear { w } = run.model else { unreachable!() };
            w[0]
        });
        report.push("SVRG epoch (sparse lazy)", sp.rows as f64, "step", &stats);
        let stats = bench_loop(1, iters.min(3), || {
            let run = train_svrg(&dense, &params, &cfg, &grad);
            let OdmModel::Linear { w } = run.model else { unreachable!() };
            w[0]
        });
        report.push("SVRG epoch (dense eager)", sp.rows as f64, "step", &stats);
    }

    // 8. compiled scoring plan vs the row-at-a-time reference (native RBF
    // batch scoring): the §Perf claim behind the infer subsystem is that the
    // batched plan clears >= 3x the single-row baseline on this workload.
    {
        use sodm::data::RowRef;
        use sodm::infer::ScoringPlan;
        let plan = ScoringPlan::compile(&model);
        let refs: Vec<RowRef> = (0..ds.rows).map(|i| RowRef::Dense(ds.row(i))).collect();
        println!("\nscoring plan section: {} rows x {} SVs", ds.rows, plan.support_size());
        let stats = bench_loop(warm, iters.min(5), || {
            refs.iter().map(|r| model.decision_rr(*r)).sum::<f64>()
        });
        report.push("score single-row naive (rbf)", ds.rows as f64, "row", &stats);
        let mut out = vec![0.0f64; refs.len()];
        let stats = bench_loop(warm, iters.min(5), || {
            plan.score_block(&refs, &mut out);
            out[0]
        });
        report.push("score plan block serial (rbf)", ds.rows as f64, "row", &stats);
        let stats = bench_loop(warm, iters.min(5), || {
            plan.score_block_parallel(&refs, sodm::util::pool::num_cpus(), &mut out);
            out[0]
        });
        report.push("score plan block parallel (rbf)", ds.rows as f64, "row", &stats);
    }

    // 9. serve worker scaling: the sharded scorer runtime under concurrent
    // synthetic load, one entry per worker count (shards track workers).
    {
        use sodm::serve::{serve, Backend, ServeConfig};
        let ncpu = sodm::util::pool::num_cpus();
        let mut counts = vec![1usize, 2, ncpu.min(4), ncpu.min(8)];
        counts.sort_unstable();
        counts.dedup();
        let clients = 8usize;
        let per_client = if quick { 30 } else { 100 };
        println!();
        for &wk in &counts {
            let cfg = ServeConfig {
                workers: wk,
                shards: wk,
                max_wait: std::time::Duration::from_millis(1),
                ..ServeConfig::default()
            };
            let h = serve(model.clone(), Backend::Native, cfg).expect("serve");
            let dsr = &ds;
            let (_, secs) = sodm::util::time_it(|| {
                std::thread::scope(|s| {
                    for c in 0..clients {
                        let h = h.clone();
                        s.spawn(move || {
                            for r in 0..per_client {
                                let _ = h.score(dsr.row((c * per_client + r * 13) % dsr.rows));
                            }
                        });
                    }
                });
            });
            h.stop();
            let stats = sodm::util::TimingStats { samples: vec![secs] };
            let total = (clients * per_client) as f64;
            report.push(&format!("serve scale w={wk}"), total, "req", &stats);
        }
    }

    // 10. multiclass OVR: K one-vs-rest class solves sharing one unsigned
    // Gram-row cache vs per-class signed caches. The models are
    // bit-identical (±1 sign application is exact), so the delta is pure
    // kernel-row amortization — the speedup entry is the acceptance number.
    {
        use sodm::multiclass::{train_ovr, MulticlassSynthSpec, OvrConfig};
        let classes = 4usize;
        let rows = if quick { 300 } else { 800 };
        let mc = MulticlassSynthSpec::new(classes, rows, 8, 23).generate();
        let kernel = KernelKind::Rbf { gamma: 1.0 / 16.0 };
        let sweeps = if quick { 20 } else { 40 };
        let budget = SolveBudget { max_sweeps: sweeps, ..SolveBudget::default() };
        println!("\nmulticlass OVR section: {classes} classes x {rows} rows");
        let shared_cfg = OvrConfig { budget, ..Default::default() };
        let private_cfg = OvrConfig { budget, share_cache: false, ..Default::default() };
        let stats_shared =
            bench_loop(0, iters.min(3), || train_ovr(&mc, &kernel, &params, &shared_cfg).seconds);
        report.push(
            "ovr train shared cache (K=4 rbf)",
            (classes * rows) as f64,
            "row-solve",
            &stats_shared,
        );
        let stats_private =
            bench_loop(0, iters.min(3), || train_ovr(&mc, &kernel, &params, &private_cfg).seconds);
        report.push(
            "ovr train per-class cache (K=4 rbf)",
            (classes * rows) as f64,
            "row-solve",
            &stats_private,
        );
        let speedup = stats_private.min() / stats_shared.min().max(1e-12);
        println!("ovr shared-cache speedup: {speedup:.2}x");
        let one = sodm::util::TimingStats { samples: vec![1.0] };
        report.push("ovr shared-cache speedup", speedup, "x", &one);
    }

    // 11. SIMD core: scalar 4-lane baseline vs the active numeric core, and
    // the f64 plan vs its quantized (f32-storage) variant — single-row,
    // serial block, parallel block, and the RFF lift. Written as the `simd`
    // summary; on the stable (no-feature) build the "core" rows measure the
    // scalar fallback, which is the point of the comparison.
    {
        use sodm::data::RowRef;
        use sodm::featmap::FeatureMap;
        use sodm::infer::{PlanPrecision, ScoringPlan};
        use sodm::simd;
        println!(
            "\nsimd section: {} core build",
            if simd::simd_enabled() { "vector (portable_simd)" } else { "scalar fallback" }
        );
        // Micro-kernel: sliding windows over one buffer so every call sees a
        // fresh slice (nothing for the optimizer to hoist out of the loop).
        let dim = 512usize;
        let reps = if quick { 4_000 } else { 20_000 };
        let buf_a: Vec<f32> = (0..dim + reps).map(|i| (i as f32 * 0.37).sin()).collect();
        let buf_b: Vec<f32> = (0..dim + reps).map(|i| (i as f32 * 0.11).cos()).collect();
        let stats = bench_loop(warm, iters, || {
            let mut s = 0.0f32;
            for r in 0..reps {
                s += simd::scalar::dot_f32(&buf_a[r..r + dim], &buf_b[r..r + dim]);
            }
            s
        });
        simd_report.push("dot d=512 scalar baseline", (reps * dim) as f64, "mul", &stats);
        let stats = bench_loop(warm, iters, || {
            let mut s = 0.0f32;
            for r in 0..reps {
                s += simd::dot_f32(&buf_a[r..r + dim], &buf_b[r..r + dim]);
            }
            s
        });
        simd_report.push("dot d=512 core", (reps * dim) as f64, "mul", &stats);

        // Plan scoring: the same trained RBF model compiled at f64 and at
        // quantized f32 coefficient storage.
        let refs: Vec<RowRef> = (0..ds.rows).map(|i| RowRef::Dense(ds.row(i))).collect();
        let mut out = vec![0.0f64; refs.len()];
        for (tag, precision) in
            [("f64", PlanPrecision::F64), ("quantized f32", PlanPrecision::F32)]
        {
            let plan = ScoringPlan::compile_with(&model, precision);
            let stats = bench_loop(warm, iters.min(5), || {
                let mut one = [0.0f64; 1];
                let mut s = 0.0;
                for r in &refs {
                    plan.score_block(std::slice::from_ref(r), &mut one);
                    s += one[0];
                }
                s
            });
            simd_report.push(&format!("plan single-row {tag}"), ds.rows as f64, "row", &stats);
            let stats = bench_loop(warm, iters.min(5), || {
                plan.score_block(&refs, &mut out);
                out[0]
            });
            simd_report.push(&format!("plan block serial {tag}"), ds.rows as f64, "row", &stats);
            let stats = bench_loop(warm, iters.min(5), || {
                plan.score_block_parallel(&refs, sodm::util::pool::num_cpus(), &mut out);
                out[0]
            });
            simd_report.push(
                &format!("plan block parallel {tag}"),
                ds.rows as f64,
                "row",
                &stats,
            );
        }

        // RFF lift: per-row vs the cache-blocked multi-row Wx kernel.
        let map = FeatureMap::rff(ds.cols, 256, 1.0, 7);
        let mut z = vec![0.0f32; refs.len() * map.dim()];
        let stats = bench_loop(warm, iters.min(3), || {
            let mut s = 0.0f32;
            for r in &refs {
                s += map.lift(*r)[0];
            }
            s
        });
        simd_report.push("rff lift per-row (D=256)", ds.rows as f64, "row", &stats);
        let stats = bench_loop(warm, iters.min(3), || {
            map.lift_block(&refs, &mut z);
            z[0]
        });
        simd_report.push("rff lift block (D=256)", ds.rows as f64, "row", &stats);
    }

    // 12-13. PJRT artifact paths (skipped without artifacts)
    match XlaEngine::load_default() {
        Some(engine) => {
            let m = engine.geometry.gram_m;
            let x1 = &ds.x[..m * ds.cols];
            let y1 = &ds.y[..m];
            let stats = bench_loop(warm, iters, || {
                engine.rbf_gram_block(x1, y1, x1, y1, ds.cols, 1.0).expect("gram")
            });
            report.push("PJRT gram block (256x256 pallas)", (m * m) as f64, "kval", &stats);

            let stats = bench_loop(warm, iters, || {
                engine
                    .odm_grad_sum(&w, &ds.x[..1024 * ds.cols], &ds.y[..1024], ds.cols, &params)
                    .expect("grad")
            });
            report.push("PJRT odm_grad (1024 pallas)", 1024.0, "row", &stats);
        }
        None => println!("(PJRT benches skipped: run `make artifacts`)"),
    }

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_json().to_string()).expect("write json summary");
        println!("\nwrote JSON summary to {path}");
    }
    if let Some(path) = simd_json_path {
        let j = Json::obj(vec![
            ("name", jstr("simd")),
            ("simd_enabled", Json::Bool(sodm::simd::simd_enabled())),
            ("benches", simd_report.benches_json()),
        ]);
        std::fs::write(&path, j.to_string()).expect("write simd json summary");
        println!("wrote SIMD summary to {path}");
    }
}
