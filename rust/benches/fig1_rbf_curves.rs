//! Bench: regenerate paper Figure 1 (RBF accuracy-vs-time curves).
use sodm::exp::figures::figure1;
use sodm::exp::ExpConfig;

fn main() {
    let cfg = ExpConfig {
        scale: 0.02,
        datasets: vec!["svmguide1".into(), "cod-rna".into()],
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let out = figure1(&cfg).expect("figure1");
    println!("{out}");
}
