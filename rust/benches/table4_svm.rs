//! Bench: regenerate paper Table 4 (SVM vs ODM meta-solvers) at bench scale.
use sodm::exp::tables::table4;
use sodm::exp::ExpConfig;

fn main() {
    let cfg = ExpConfig {
        scale: 0.02,
        datasets: vec!["svmguide1".into(), "phishing".into()],
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = table4(&cfg).expect("table4");
    println!("{out}");
    println!("bench total: {:.2}s", t0.elapsed().as_secs_f64());
}
