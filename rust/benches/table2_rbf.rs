//! Bench: regenerate paper Table 2 (RBF kernel, 5 QP methods) at bench scale.
//! `cargo bench --bench table2_rbf` — see EXPERIMENTS.md for full-scale runs.
use sodm::exp::tables::table2;
use sodm::exp::ExpConfig;

fn main() {
    let cfg = ExpConfig {
        scale: 0.02,
        datasets: vec!["svmguide1".into(), "cod-rna".into(), "ijcnn1".into()],
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = table2(&cfg).expect("table2");
    println!("{out}");
    println!("bench total: {:.2}s", t0.elapsed().as_secs_f64());
}
