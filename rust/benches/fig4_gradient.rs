//! Bench: regenerate paper Figure 4 (gradient-based methods).
use sodm::exp::figures::figure4;
use sodm::exp::ExpConfig;

fn main() {
    let cfg = ExpConfig {
        scale: 0.02,
        datasets: vec!["svmguide1".into(), "SUSY".into()],
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let out = figure4(&cfg).expect("figure4");
    println!("{out}");
}
