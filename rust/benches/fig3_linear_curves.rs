//! Bench: regenerate paper Figure 3 (linear accuracy-vs-time curves).
use sodm::exp::figures::figure3;
use sodm::exp::ExpConfig;

fn main() {
    let cfg = ExpConfig {
        scale: 0.02,
        datasets: vec!["svmguide1".into(), "a7a".into()],
        out_dir: "results/bench".into(),
        ..Default::default()
    };
    let out = figure3(&cfg).expect("figure3");
    println!("{out}");
}
