//! End-to-end system driver — proves all three layers compose.
//!
//! For every emulated paper dataset (scaled): generate data → build
//! distribution-aware partitions → train SODM (Algorithm 1, RBF) and the
//! DSVRG linear accelerator (Algorithm 2) on the simulated cluster → serve
//! batched predictions through the **AOT Pallas/PJRT artifacts** (L1/L2)
//! and cross-check them against the rust-native decision path → report
//! accuracy, train time, serving latency/throughput, and communication.
//!
//! This is the EXPERIMENTS.md §E2E driver. Requires `make artifacts`.
//!
//! Run with: `cargo run --release --example e2e_benchmark [scale]`

use std::time::Instant;

use sodm::cluster::SimCluster;
use sodm::data::synth::SynthSpec;
use sodm::exp::rbf_for;
use sodm::odm::{OdmModel, OdmParams};
use sodm::partition::PartitionStrategy;
use sodm::qp::SolveBudget;
use sodm::runtime::XlaEngine;
use sodm::sodm::{train_sodm, SodmConfig};
use sodm::svrg::{train_dsvrg, NativeGrad, SvrgConfig};

fn main() {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.02);
    let engine = XlaEngine::load_default().expect(
        "AOT artifacts not found — run `make artifacts` first (python lowers the \
         Pallas kernels to HLO text once; rust is self-contained afterwards)",
    );
    println!(
        "PJRT engine up: feature buckets {:?}, gram tile {}x{}, grad batch {}\n",
        engine.geometry.feature_buckets,
        engine.geometry.gram_m,
        engine.geometry.gram_p,
        engine.geometry.grad_b
    );

    println!(
        "{:<14}{:>7}{:>10}{:>10}{:>10}{:>12}{:>12}{:>12}{:>10}",
        "dataset", "rows", "rbf acc", "rbf t(s)", "lin acc", "lin t(s)", "serve ms/b", "serve q/s", "max |Δ|"
    );

    let mut worst_delta_all: f64 = 0.0;
    for spec in SynthSpec::all(scale, 9) {
        let ds = spec.generate();
        let (train, test) = ds.split(0.8, 9);
        let kernel = rbf_for(&train);
        let params = OdmParams::default();
        let cluster = SimCluster::new(8);

        // --- L3: SODM hierarchical merge training (RBF) ---
        let t0 = Instant::now();
        let rbf_model = train_sodm(
            &train,
            &kernel,
            &params,
            &SodmConfig {
                p: 4,
                levels: 2,
                stratums: 16,
                strategy: PartitionStrategy::StratifiedRkhs { stratums: 16 },
                budget: SolveBudget { max_sweeps: 40, ..Default::default() },
                level_tol: 1e-3,
                final_exact: train.rows <= 6000,
                seed: 9,
            },
            Some(&cluster),
        );
        let rbf_secs = t0.elapsed().as_secs_f64();

        // --- L3: DSVRG linear accelerator ---
        let t1 = Instant::now();
        let lin_run = train_dsvrg(
            &train,
            &params,
            &SvrgConfig { epochs: 3, partitions: 8, seed: 9, ..Default::default() },
            Some(&cluster),
            &NativeGrad { workers: 1 },
        );
        let lin_secs = t1.elapsed().as_secs_f64();

        // --- L1/L2 serving: batched decisions through the PJRT artifacts ---
        let batch = engine.geometry.dec_b;
        let n_batches = test.rows.div_ceil(batch);
        let (xla_decisions, serve_secs) = match &rbf_model {
            OdmModel::Kernel { kernel: k, sv_x, coef, cols } => {
                let sodm::kernel::KernelKind::Rbf { gamma } = k else { unreachable!() };
                let t2 = Instant::now();
                let dec = engine
                    .rbf_decisions(sv_x, coef, &test.x, *cols, *gamma)
                    .expect("pjrt decision");
                (dec, t2.elapsed().as_secs_f64())
            }
            OdmModel::Linear { w } => {
                let t2 = Instant::now();
                let dec = engine.linear_decisions(w, &test.x, test.cols).expect("pjrt");
                (dec, t2.elapsed().as_secs_f64())
            }
            OdmModel::SparseKernel { .. } => {
                unreachable!("dense training keeps dense SV storage")
            }
        };
        // cross-check against the native path (same math, different engine)
        let native_decisions = rbf_model.decisions(&test);
        let mut worst = 0.0f64;
        for (a, b) in xla_decisions.iter().zip(&native_decisions) {
            worst = worst.max((a - b).abs());
        }
        worst_delta_all = worst_delta_all.max(worst);
        let xla_acc = xla_decisions
            .iter()
            .zip(&test.y)
            .filter(|(d, y)| (**d >= 0.0) == (**y > 0.0))
            .count() as f64
            / test.rows as f64;
        assert!(
            (xla_acc - rbf_model.accuracy(&test)).abs() < 1e-9,
            "XLA and native serving disagree on accuracy"
        );

        println!(
            "{:<14}{:>7}{:>10.4}{:>10.2}{:>10.4}{:>12.2}{:>12.2}{:>12.0}{:>10.2e}",
            train.name,
            train.rows,
            xla_acc,
            rbf_secs,
            lin_run.model.accuracy(&test),
            lin_secs,
            serve_secs * 1e3 / n_batches as f64,
            test.rows as f64 / serve_secs,
            worst
        );
    }
    println!(
        "\nnative-vs-PJRT decision agreement: max |Δ| = {worst_delta_all:.2e} (f32 artifact vs f64 native)"
    );
    let counts = engine.execution_counts();
    let mut names: Vec<_> = counts.keys().collect();
    names.sort();
    println!("PJRT executions:");
    for n in names {
        println!("  {n}: {}", counts[n]);
    }
}
