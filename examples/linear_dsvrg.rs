//! DSVRG at scale — paper Algorithm 2 on the SUSY-like emulated dataset,
//! through the `sodm::api` facade.
//!
//! Shows the communication-efficiency story: per-epoch traffic of the
//! center-broadcast / parallel-gradient / round-robin-update schedule, the
//! objective trajectory, and the comparison against single-machine SVRG and
//! coreset SVRG (the Fig. 4 trio — three specs, one `api::train` entry
//! point).
//!
//! Run with: `cargo run --release --example linear_dsvrg`

use sodm::api::{self, Method, TrainSpec};
use sodm::cluster::SimCluster;
use sodm::data::{all_indices, synth::SynthSpec, DataView};
use sodm::odm::OdmParams;
use sodm::svrg::primal_objective;

fn main() -> sodm::Result<()> {
    // SUSY geometry (18 features) at a workstation-friendly size.
    let ds = SynthSpec::named("SUSY", 0.04, 3).generate(); // 20k rows
    let (train, test) = ds.split(0.8, 3);
    println!("dataset {} ({} train rows, {} features)\n", train.name, train.rows, train.cols);
    let spec = |m: Method| TrainSpec::new(m).epochs(4).partitions(8).workers(1).build();

    // DSVRG (Algorithm 2) with communication accounting.
    let cluster = SimCluster::new(8);
    let run = api::train_run(&spec(Method::Dsvrg)?, &train, Some(&cluster))?;
    let comm = cluster.comm();
    println!(
        "DSVRG: {:.2}s, test acc {:.4}",
        run.artifact.meta.seconds,
        run.artifact.accuracy(&test)?
    );
    println!(
        "  communication: {} rounds, {} messages, {:.2} MiB total, {:.1} ms simulated network time",
        comm.rounds,
        comm.messages,
        comm.bytes as f64 / (1 << 20) as f64,
        comm.simulated_seconds(&cluster.model) * 1e3,
    );
    println!("  objective trajectory (per 1/3 epoch):");
    for s in run.snapshots.iter().take(9) {
        println!("    +{:.2}s: objective {:.5}", s.elapsed, s.objective);
    }

    // The Fig. 4 trio on the same data.
    println!("\ngradient-method comparison (same epochs):");
    let idx = all_indices(&train);
    let view = DataView::new(&train, &idx);
    let svrg = api::train(&spec(Method::Svrg)?, &train)?;
    let csvrg = api::train(&spec(Method::Csvrg)?, &train)?;
    println!("{:<12}{:>10}{:>12}{:>14}", "method", "time(s)", "test acc", "objective");
    for artifact in [&run.artifact, &svrg, &csvrg] {
        let sodm::odm::OdmModel::Linear { w } = artifact.as_binary().expect("linear model") else {
            unreachable!("gradient methods train linear models")
        };
        println!(
            "{:<12}{:>10.2}{:>12.4}{:>14.5}",
            artifact.meta.method,
            artifact.meta.seconds,
            artifact.accuracy(&test)?,
            primal_objective(w, &view, &OdmParams::default(), 1)
        );
    }
    Ok(())
}
