//! DSVRG at scale — paper Algorithm 2 on the SUSY-like emulated dataset.
//!
//! Shows the communication-efficiency story: per-epoch traffic of the
//! center-broadcast / parallel-gradient / round-robin-update schedule, the
//! objective trajectory, and the comparison against single-machine SVRG and
//! coreset SVRG (the Fig. 4 trio).
//!
//! Run with: `cargo run --release --example linear_dsvrg`

use sodm::cluster::SimCluster;
use sodm::data::{all_indices, synth::SynthSpec, DataView};
use sodm::odm::OdmParams;
use sodm::svrg::{
    primal_objective, train_csvrg, train_dsvrg, train_svrg, NativeGrad, SvrgConfig,
};

fn main() {
    // SUSY geometry (18 features) at a workstation-friendly size.
    let ds = SynthSpec::named("SUSY", 0.04, 3).generate(); // 20k rows
    let (train, test) = ds.split(0.8, 3);
    println!(
        "dataset {} ({} train rows, {} features)\n",
        train.name, train.rows, train.cols
    );
    let params = OdmParams::default();
    let cfg = SvrgConfig { epochs: 4, partitions: 8, ..Default::default() };
    let grad = NativeGrad { workers: 1 };

    // DSVRG (Algorithm 2) with communication accounting.
    let cluster = SimCluster::new(8);
    let run = train_dsvrg(&train, &params, &cfg, Some(&cluster), &grad);
    let comm = cluster.comm();
    println!("DSVRG: {:.2}s, test acc {:.4}", run.total_seconds, run.model.accuracy(&test));
    println!(
        "  communication: {} rounds, {} messages, {:.2} MiB total, {:.1} ms simulated network time",
        comm.rounds,
        comm.messages,
        comm.bytes as f64 / (1 << 20) as f64,
        comm.simulated_seconds(&cluster.model) * 1e3,
    );
    println!("  objective trajectory (per 1/3 epoch):");
    for c in run.checkpoints.iter().take(9) {
        println!(
            "    epoch {} +{:.2}: objective {:.5} ({:.2}s)",
            c.epoch, c.fraction, c.objective, c.elapsed
        );
    }

    // The Fig. 4 trio on the same data.
    println!("\ngradient-method comparison (same epochs):");
    let idx = all_indices(&train);
    let view = DataView::new(&train, &idx);
    let t0 = std::time::Instant::now();
    let svrg = train_svrg(&train, &params, &cfg, &grad);
    let svrg_secs = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let csvrg = train_csvrg(&train, &params, &cfg, &grad);
    let csvrg_secs = t1.elapsed().as_secs_f64();
    println!("{:<12}{:>10}{:>12}{:>14}", "method", "time(s)", "test acc", "objective");
    for (name, secs, model) in [
        ("DSVRG", run.total_seconds, &run.model),
        ("SVRG", svrg_secs, &svrg.model),
        ("CSVRG", csvrg_secs, &csvrg.model),
    ] {
        let sodm::odm::OdmModel::Linear { w } = model else { unreachable!() };
        println!(
            "{:<12}{:>10.2}{:>12.4}{:>14.5}",
            name,
            secs,
            model.accuracy(&test),
            primal_objective(w, &view, &params, 1)
        );
    }
}
