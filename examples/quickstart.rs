//! Quickstart: train SODM through the `sodm::api` facade, compare against
//! the exact single-machine ODM, and look at the margin distribution the
//! method is named after.
//!
//! Run with: `cargo run --release --example quickstart`

use sodm::api::{self, Method, TrainSpec};
use sodm::data::synth::SynthSpec;
use sodm::kernel::KernelKind;
use sodm::odm::margin_stats;

fn main() -> sodm::Result<()> {
    // 1. An emulated benchmark: svmguide1 geometry (7089 x 4) at 30% size.
    let ds = SynthSpec::named("svmguide1", 0.3, 42).generate();
    let (train, test) = ds.split(0.8, 42);
    println!(
        "dataset: {} ({} train / {} test rows, {} features)",
        train.name, train.rows, test.rows, train.cols
    );

    let kernel = KernelKind::Rbf { gamma: 1.0 };

    // 2. Exact ODM — the reference the paper calls "ODM".
    let exact_spec = TrainSpec::new(Method::ExactOdm).kernel(kernel).build()?;
    let exact = api::train(&exact_spec, &train)?;

    // 3. SODM — Algorithm 1 with the distribution-aware partitioner,
    // through the same facade: only the spec changes.
    let sodm_spec = TrainSpec::new(Method::Sodm).kernel(kernel).tree(4, 2, 16).build()?;
    let run = api::train_run(&sodm_spec, &train, None)?;

    println!("\n{:<12}{:>10}{:>12}{:>14}", "method", "time(s)", "test acc", "support size");
    for artifact in [&exact, &run.artifact] {
        println!(
            "{:<12}{:>10.2}{:>12.4}{:>14}",
            artifact.meta.method,
            artifact.meta.seconds,
            artifact.accuracy(&test)?,
            artifact.support_size()
        );
    }

    // 4. The per-level trace: every snapshot along the hierarchical merge
    // is a usable model.
    println!("\nSODM level trace (Algorithm 1):");
    for snap in &run.snapshots {
        println!(
            "  {:>3} partitions, {:.2}s elapsed, block-diag objective {:.4}, acc {:.4}",
            snap.partitions,
            snap.elapsed,
            snap.objective,
            snap.model.accuracy(&test)
        );
    }

    // 5. The margin distribution (what ODM optimizes): mean ~1, small variance.
    let sodm_model = run.artifact.as_binary().expect("binary spec trains a binary model");
    let exact_model = exact.as_binary().expect("binary spec trains a binary model");
    let (mean, var) = margin_stats(sodm_model, &train);
    println!("\nmargin distribution on train: mean {mean:.3}, variance {var:.3}");
    let (emean, evar) = margin_stats(exact_model, &train);
    println!("exact ODM reference:          mean {emean:.3}, variance {evar:.3}");
    Ok(())
}
