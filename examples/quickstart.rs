//! Quickstart: train SODM on an emulated benchmark, compare against the
//! exact single-machine ODM, and look at the margin distribution the method
//! is named after.
//!
//! Run with: `cargo run --release --example quickstart`

use sodm::data::synth::SynthSpec;
use sodm::kernel::KernelKind;
use sodm::odm::{margin_stats, train_exact_odm, OdmParams};
use sodm::qp::SolveBudget;
use sodm::sodm::{train_sodm_traced, SodmConfig};

fn main() {
    // 1. An emulated benchmark: svmguide1 geometry (7089 x 4) at 30% size.
    let ds = SynthSpec::named("svmguide1", 0.3, 42).generate();
    let (train, test) = ds.split(0.8, 42);
    println!("dataset: {} ({} train / {} test rows, {} features)",
        train.name, train.rows, test.rows, train.cols);

    let kernel = KernelKind::Rbf { gamma: 1.0 };
    let params = OdmParams::default();

    // 2. Exact ODM — the reference the paper calls "ODM".
    let t0 = std::time::Instant::now();
    let exact = train_exact_odm(&train, &kernel, &params, &SolveBudget::default());
    let exact_secs = t0.elapsed().as_secs_f64();

    // 3. SODM — Algorithm 1 with the distribution-aware partitioner.
    let run = train_sodm_traced(
        &train,
        &kernel,
        &params,
        &SodmConfig::with_tree(4, 2, 16),
        None,
    );

    println!("\n{:<12}{:>10}{:>12}{:>14}", "method", "time(s)", "test acc", "support size");
    println!(
        "{:<12}{:>10.2}{:>12.4}{:>14}",
        "ODM", exact_secs, exact.accuracy(&test), exact.support_size()
    );
    println!(
        "{:<12}{:>10.2}{:>12.4}{:>14}",
        "SODM", run.total_seconds, run.model.accuracy(&test), run.model.support_size()
    );

    // 4. The hierarchical merge trace: each level is a usable model.
    println!("\nSODM level trace (Algorithm 1):");
    for level in &run.trace {
        println!(
            "  level {:>2}: {:>3} partitions, {:.2}s elapsed, block-diag objective {:.4}, acc {:.4}",
            level.level,
            level.n_partitions,
            level.elapsed,
            level.objective,
            level.model.accuracy(&test)
        );
    }

    // 5. The margin distribution (what ODM optimizes): mean ~1, small variance.
    let (mean, var) = margin_stats(&run.model, &train);
    println!("\nmargin distribution on train: mean {mean:.3}, variance {var:.3}");
    let (emean, evar) = margin_stats(&exact, &train);
    println!("exact ODM reference:          mean {emean:.3}, variance {evar:.3}");
}
