//! Partition-strategy demo — the paper's §3.2 story, measurable.
//!
//! Compares the distribution preservation of the four partitioners (random /
//! stratified-RKHS / k-means-proportional / kernel-k-means-clusters) and
//! shows why SODM's stratified partitions make local solutions land near the
//! global one: per-partition label balance, feature-mean drift, landmark
//! diversity (Gram log-det / principal angle, Theorem 2), and the local-vs-
//! global dual objective gap (Theorem 1's quantity).
//!
//! Run with: `cargo run --release --example partition_demo`

use sodm::data::{all_indices, synth::SynthSpec, DataView};
use sodm::kernel::KernelKind;
use sodm::odm::OdmParams;
use sodm::partition::landmarks::Nystrom;
use sodm::partition::{
    label_balance_gap, make_partitions, mean_shift_gap, PartitionStrategy,
};
use sodm::qp::{odm_dual_objective, solve_odm_dual, SolveBudget};

fn main() {
    let ds = SynthSpec::named("phishing", 0.15, 11).generate();
    let idx = all_indices(&ds);
    let view = DataView::new(&ds, &idx);
    let kernel = KernelKind::Rbf { gamma: 1.0 };
    let params = OdmParams::default();
    let k = 8;
    println!(
        "dataset {} ({} rows, {} features), {} partitions\n",
        ds.name, ds.rows, ds.cols, k
    );

    // Global reference solution (for the Theorem-1 gap).
    let budget = SolveBudget { max_sweeps: 60, ..Default::default() };
    let global = solve_odm_dual(&view, &kernel, &params, None, &budget);
    println!("global ODM dual objective: {:.4}\n", global.stats.objective);

    println!(
        "{:<26}{:>12}{:>12}{:>16}{:>16}",
        "strategy", "label gap", "mean drift", "sum local obj", "theorem-1 gap"
    );
    for (name, strategy) in [
        ("random (Cascade)", PartitionStrategy::Random),
        ("stratified RKHS (SODM)", PartitionStrategy::StratifiedRkhs { stratums: 16 }),
        ("kmeans proportional (DiP)", PartitionStrategy::KmeansProportional { clusters: 8 }),
        ("kernel-kmeans (DC)", PartitionStrategy::KernelKmeansClusters { embed_dim: 16 }),
    ] {
        let parts = make_partitions(&view, &kernel, k, strategy, 3, 1);
        let lg = label_balance_gap(&view, &parts);
        let mg = mean_shift_gap(&view, &parts);
        // Solve each local ODM; the block-diagonal objective (Eqn. 4) vs the
        // global optimum is exactly what Theorem 1 bounds.
        let mut local_sum = 0.0;
        for p in &parts {
            let pv = DataView::new(&ds, p);
            let sol = solve_odm_dual(&pv, &kernel, &params, None, &budget);
            local_sum += sol.stats.objective;
        }
        // Evaluate the concatenated local solution under the TRUE dual
        // d(ζ̃*, β̃*) — the left side of Theorem 1's Eqn. (5).
        let concat_idx: Vec<usize> = parts.iter().flatten().copied().collect();
        let cview = DataView::new(&ds, &concat_idx);
        let mut zeta = Vec::new();
        let mut beta = Vec::new();
        for p in &parts {
            let pv = DataView::new(&ds, p);
            let sol = solve_odm_dual(&pv, &kernel, &params, None, &budget);
            zeta.extend(sol.zeta);
            beta.extend(sol.beta);
        }
        let d_tilde = odm_dual_objective(&cview, &kernel, &params, &zeta, &beta);
        let gap = d_tilde - global.stats.objective;
        println!("{name:<26}{lg:>12.4}{mg:>12.4}{local_sum:>16.4}{gap:>16.4}");
    }

    // Landmark diagnostics (Theorem 2's quantities).
    println!("\nlandmark selection (greedy det-max, Eqn. 8):");
    let ny = Nystrom::select(&view, &kernel, 16, 2048, 5);
    println!("  landmarks selected: {}", ny.len());
    println!("  Gram log-det:       {:.3}", ny.gram_logdet());
    if let Some(tau) = ny.min_principal_angle() {
        println!("  min principal angle tau: {:.3} rad (cos tau = {:.3})", tau, tau.cos());
    }
}
