//! End-to-end `sodm::api` walkthrough: build a validated spec, train, save
//! the versioned artifact, load it back, serve it, and score requests.
//!
//! Run with: `cargo run --release --example train_api`

use sodm::api::{self, Artifact, Method, TrainSpec};
use sodm::data::synth::SynthSpec;
use sodm::kernel::KernelKind;
use sodm::serve::ServeConfig;

fn main() -> sodm::Result<()> {
    // 1. Data: an emulated svmguide1 at 5% size.
    let ds = SynthSpec::named("svmguide1", 0.05, 7).generate();
    let (train, test) = ds.split(0.8, 7);

    // 2. Spec: method x kernel x hyperparameters, validated at build time.
    //    (Try Method::Dsvrg with this RBF kernel: build() returns the typed
    //    SpecError::LinearOnly instead of failing somewhere in a trainer.)
    let spec = TrainSpec::new(Method::Sodm)
        .kernel(KernelKind::Rbf { gamma: 1.0 })
        .tree(4, 2, 16)
        .seed(7)
        .build()?;

    // 3. Train: one entry point for every method.
    let artifact = api::train(&spec, &train)?;
    println!(
        "trained method={} in {:.2}s: test accuracy {:.4}, {} support vectors",
        artifact.meta.method,
        artifact.meta.seconds,
        artifact.accuracy(&test)?,
        artifact.support_size()
    );

    // 4. Save / load the versioned artifact (format_version + model + meta;
    //    pre-facade v0 model JSON loads through the same entry point).
    let dir = sodm::util::temp_dir("train-api-example");
    let path = dir.join("model.json");
    artifact.save(&path)?;
    let loaded = Artifact::load(&path)?;
    println!("reloaded artifact: method={} kernel={:?}", loaded.meta.method, loaded.meta.kernel);

    // 5. Serve the loaded artifact and score a few rows (into_serve moves
    //    the support vectors into the server — no clone).
    let handle = loaded.into_serve(ServeConfig::default())?;
    for i in 0..3 {
        let decision = handle.score(test.row(i))?;
        println!("row {i}: decision {decision:+.4} (label {:+.0})", test.y[i]);
    }
    handle.stop();
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}
