//! Multiclass quickstart: generate a 4-class dataset, train one-vs-rest
//! ODMs through the `sodm::api` facade (shared Gram-row cache), round-trip
//! the versioned artifact, and serve `score_multiclass` requests.
//!
//! Run with: `cargo run --release --example multiclass`

use sodm::api::{self, Artifact, Method, OvrOptions, TrainSpec};
use sodm::kernel::KernelKind;
use sodm::multiclass::MulticlassSynthSpec;
use sodm::serve::ServeConfig;

fn main() -> sodm::Result<()> {
    // 1. A 4-class Gaussian-blob dataset (8 features, well separated).
    let ds = MulticlassSynthSpec::new(4, 1200, 8, 7).generate();
    let (train, test) = ds.split(0.8, 7);
    println!(
        "dataset: {} ({} train / {} test rows, {} classes, {} features)",
        train.name(),
        train.rows(),
        test.rows(),
        train.n_classes(),
        train.cols()
    );

    // 2. One-vs-rest training through the facade: the K class solves run in
    // parallel on the pool workers, all reading one shared unsigned
    // Gram-row cache (the kernel matrix is label-independent, so every
    // class reuses each row).
    let spec = TrainSpec::new(Method::ExactOdm)
        .kernel(KernelKind::Rbf { gamma: 1.0 / 16.0 })
        .multiclass(OvrOptions::default())
        .build()?;
    let run = api::train_run(&spec, &train, None)?;
    println!(
        "trained {} classes in {:.2}s (shared-cache hit rate {:.2}, {} SVs total)",
        run.artifact.n_classes().unwrap_or(0),
        run.artifact.meta.seconds,
        run.cache_hit_rate,
        run.artifact.support_size()
    );
    println!("test accuracy: {:.4}", run.artifact.accuracy_multiclass(&test, 4)?);

    // 3. Save / load round-trip through the versioned artifact format
    // (bit-exact: decisions are identical).
    let dir = sodm::util::temp_dir("multiclass-example");
    let path = dir.join("multiclass.json");
    run.artifact.save(&path)?;
    let artifact = Artifact::load(&path)?;

    // 4. Serve it: score_multiclass returns the argmax class plus every
    // class's one-vs-rest margin, sharded across the scorer workers.
    let handle = artifact.serve(ServeConfig::default())?;
    let model = artifact.as_multiclass().expect("multiclass artifact");
    let rows = test.as_rows();
    for i in 0..4 {
        let reply = handle.score_multiclass(rows.row(i))?;
        let rounded: Vec<f64> = reply.scores.iter().map(|s| (s * 10.0).round() / 10.0).collect();
        println!(
            "row {i}: predicted class {} (label {}), margins {rounded:?}",
            reply.argmax, model.class_labels[reply.argmax]
        );
    }
    handle.stop();
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}
