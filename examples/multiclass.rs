//! Multiclass quickstart: generate a 4-class dataset, train one-vs-rest
//! ODMs with the shared Gram-row cache, round-trip the model through JSON,
//! and serve `score_multiclass` requests.
//!
//! Run with: `cargo run --release --example multiclass`

use sodm::kernel::KernelKind;
use sodm::multiclass::{train_ovr, MulticlassModel, MulticlassSynthSpec, OvrConfig};
use sodm::odm::OdmParams;
use sodm::serve::{serve_multiclass, ServeConfig};

fn main() {
    // 1. A 4-class Gaussian-blob dataset (8 features, well separated).
    let ds = MulticlassSynthSpec::new(4, 1200, 8, 7).generate();
    let (train, test) = ds.split(0.8, 7);
    println!(
        "dataset: {} ({} train / {} test rows, {} classes, {} features)",
        train.name(),
        train.rows(),
        test.rows(),
        train.n_classes(),
        train.cols()
    );

    // 2. One-vs-rest training: the K class solves run in parallel on the
    // pool workers, all reading one shared unsigned Gram-row cache (the
    // kernel matrix is label-independent, so every class reuses each row).
    let kernel = KernelKind::Rbf { gamma: 1.0 / 16.0 };
    let run = train_ovr(&train, &kernel, &OdmParams::default(), &OvrConfig::default());
    println!(
        "trained {} classes in {:.2}s (shared-cache hit rate {:.2}, {} SVs total)",
        run.model.n_classes(),
        run.seconds,
        run.cache_hit_rate,
        run.model.support_size()
    );
    println!("test accuracy: {:.4}", run.model.accuracy(&test, 4));

    // 3. Save / load round-trip (bit-exact: decisions are identical).
    let dir = sodm::util::temp_dir("multiclass-example");
    let path = dir.join("multiclass.json");
    run.model.save(&path).expect("save model");
    let model = MulticlassModel::load(&path).expect("load model");

    // 4. Serve it: score_multiclass returns the argmax class plus every
    // class's one-vs-rest margin, sharded across the scorer workers.
    let handle = serve_multiclass(model, ServeConfig::default()).expect("serve");
    let rows = test.as_rows();
    for i in 0..4 {
        let reply = handle.score_multiclass(rows.row(i)).expect("score");
        let rounded: Vec<f64> = reply.scores.iter().map(|s| (s * 10.0).round() / 10.0).collect();
        println!(
            "row {i}: predicted class {} (label {}), margins {rounded:?}",
            reply.argmax, run.model.class_labels[reply.argmax]
        );
    }
    handle.stop();
    std::fs::remove_dir_all(dir).ok();
}
