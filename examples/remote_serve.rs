//! Remote serving: the full network stack in one process — train a model,
//! put the TCP frontend in front of the batched scoring runtime, score
//! over the wire, then hot-swap the artifact under live traffic and watch
//! the version flip without dropping a request.
//!
//! Run with: `cargo run --release --example remote_serve`

use std::sync::Arc;

use sodm::api::{self, Method, TrainSpec};
use sodm::data::synth::SynthSpec;
use sodm::kernel::KernelKind;
use sodm::net::{ModelRegistry, NetClient, NetServer};
use sodm::serve::ServeConfig;

fn main() -> sodm::Result<()> {
    if std::net::TcpListener::bind("127.0.0.1:0").is_err() {
        eprintln!("loopback sockets unavailable in this environment; nothing to demo");
        return Ok(());
    }

    // 1. Train two generations of the model: v1 serves first, v2 waits on
    // disk for the hot swap.
    let spec = TrainSpec::new(Method::ExactOdm).kernel(KernelKind::Rbf { gamma: 1.0 }).build()?;
    let mut sgen = SynthSpec::named("svmguide1", 0.02, 7);
    sgen.rows = 240;
    let ds = sgen.generate();
    let v1 = api::train(&spec, &ds)?;
    let mut sgen2 = SynthSpec::named("svmguide1", 0.02, 43);
    sgen2.rows = 240;
    let v2 = api::train(&spec, &sgen2.generate())?;
    let swap_path = std::env::temp_dir().join("sodm_example_vnext.json");
    v2.save(&swap_path)?;

    // 2. Registry + TCP frontend on an ephemeral loopback port.
    let cfg = ServeConfig { workers: 2, shards: 2, ..ServeConfig::default() };
    let registry = Arc::new(ModelRegistry::start(v1, cfg)?);
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&registry))?;
    let addr = server.local_addr();
    println!("serving on {addr}");

    // 3. Score over the wire; a second connection probes health.
    let mut client = NetClient::connect(addr)?;
    let x = ds.row(0);
    println!("wire score of row 0: {:+.4}", client.score(x)?.value()?);
    println!("health: {}", client.health()?);

    // 4. Hot swap to v2 while the scoring connection stays open. In-flight
    // batches drain on the old plan; new requests route to the new one.
    let version = client.admin_swap(swap_path.to_str().expect("utf-8 temp path"))?;
    println!("hot-swapped to version {version}");
    println!("wire score of row 0 on v{version}: {:+.4}", client.score(x)?.value()?);
    println!("health: {}", client.health()?);

    // 5. Metrics come from the live generation's serving runtime.
    println!("metrics: {}", client.metrics()?);

    server.stop();
    let _ = std::fs::remove_file(&swap_path);
    Ok(())
}
